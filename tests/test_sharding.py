"""Sharding-annotation tests (paper §3 "Sharding DrJAX computations", Fig. 6).

These must run with multiple XLA host devices, but the device count is locked
at first JAX init — and the rest of the suite must see ONE device. So each
script runs inside the shared multi-device worker (conftest.device_pool).
Mesh construction and ambient-mesh contexts go through ``repro.compat`` so
the same scripts work across JAX versions (AxisType / ``jax.set_mesh`` exist
only on newer releases).
"""

import textwrap

import pytest

_PRELUDE = """
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro import compat
    from repro import core as drjax

    mesh = compat.make_mesh((jax.device_count(),), ("data",))
"""


def _run(device_pool, body: str) -> dict:
    return device_pool.run(
        textwrap.dedent(_PRELUDE) + textwrap.dedent(body)
    )


@pytest.mark.slow
def test_partitioned_value_is_sharded_over_data_axis(device_pool):
    res = _run(
        device_pool,
        """
        @drjax.program(partition_size=8, partition_axes="data", mesh=mesh)
        def f(x):
            y = drjax.broadcast(x)          # (8, 1024) partitioned
            z = drjax.map_fn(lambda a: a * 2.0, y)
            return drjax.reduce_sum(z)

        x = jnp.ones((1024,), jnp.float32)
        with compat.set_mesh(mesh):
            lowered = jax.jit(f).lower(x)
            compiled = lowered.compile()
        # output correct under sharding
        np.testing.assert_allclose(np.asarray(jax.jit(f)(x)), 16.0 * np.ones(1024))
        mem = compiled.memory_analysis()
        print(json.dumps({"temp": mem.temp_size_in_bytes,
                          "ok": True}))
        """,
    )
    assert res["ok"]


@pytest.mark.slow
def test_ns_ablation_memory_blowup(device_pool):
    """DrJAX vs DrJAX-NS: without annotations the partitioned intermediate is
    replicated per device; with annotations it is sharded 1/m. (Fig. 6)"""
    res = _run(
        device_pool,
        """
        D = 256

        def build(use_ann):
            @drjax.program(partition_size=8, partition_axes="data", mesh=mesh,
                           use_sharding_annotations=use_ann)
            def f(w):
                wb = drjax.broadcast(w)                  # (8, D, D) model copies

                def local_steps(wi):
                    # two dependent "local steps": matmuls force the
                    # partitioned copies to materialize (no full fusion).
                    for _ in range(2):
                        wi = jnp.tanh(wi @ wi)
                    return wi

                z = drjax.map_fn(local_steps, wb)
                return drjax.reduce_mean(z)
            return f

        from jax.sharding import NamedSharding, PartitionSpec as P
        w = jax.ShapeDtypeStruct((D, D), jnp.float32,
                                 sharding=NamedSharding(mesh, P(None, None)))
        stats = {}
        for name, ann in [("drjax", True), ("ns", False)]:
            with compat.set_mesh(mesh):
                c = jax.jit(build(ann)).lower(w).compile()
            m = c.memory_analysis()
            stats[name] = m.temp_size_in_bytes
        print(json.dumps(stats))
        """,
    )
    # with annotations the big (8, D) partitioned temps live sharded (1/8 per
    # device); the NS program keeps at least one fully-replicated copy.
    assert res["drjax"] < res["ns"], res


@pytest.mark.slow
def test_logical_partition_decoupled_from_device_count(device_pool):
    """partition_size n shards over m devices for any m | n (paper §3)."""
    res = _run(
        device_pool,
        """
        @drjax.program(partition_size=32, partition_axes="data", mesh=mesh)
        def f(x):
            y = drjax.broadcast(x)      # 32 logical groups over the devices
            z = drjax.map_fn(lambda a: a ** 2, y)
            return drjax.reduce_sum(z)

        with compat.set_mesh(mesh):
            out = jax.jit(f)(jnp.float32(2.0))
        print(json.dumps({"out": float(out)}))
        """,
    )
    assert res["out"] == 32 * 4.0


@pytest.mark.slow
def test_post_reduce_value_is_replicated(device_pool):
    """Regression: constrain_replicated must actually replicate. The old
    all-UNCONSTRAINED spec constrained nothing, so GSPMD could leave a
    partition axis on a post-reduce (server-placed) value."""
    res = _run(
        device_pool,
        """
        @drjax.program(partition_size=8, partition_axes="data", mesh=mesh)
        def f(x):
            y = drjax.broadcast(x)
            z = drjax.map_fn(lambda a: a * 2.0, y)
            return drjax.reduce_sum(z)

        x = jnp.ones((1024,), jnp.float32)
        with compat.set_mesh(mesh):
            out = jax.jit(f)(x)
        np.testing.assert_allclose(np.asarray(out), 16.0 * np.ones(1024))
        print(json.dumps({
            "replicated": bool(out.sharding.is_fully_replicated),
        }))
        """,
    )
    assert res["replicated"], "post-reduce value still carries a partition axis"


@pytest.mark.slow
def test_nested_placements_shard_per_placement(device_pool):
    """A nested {pods, clients} program on a (pod, data) mesh: each
    placement's group axis pins its own mesh axis and the program computes
    the right thing under jit."""
    res = _run(
        device_pool,
        """
        n = jax.device_count()
        pod_mesh = compat.make_mesh((2, n // 2), ("pod", "data"))
        from repro.launch.mesh import placement_axes_for
        axes = placement_axes_for(pod_mesh)
        assert axes == {"pods": "pod", "clients": "data"}, axes

        @drjax.program(placements={"pods": 2, "clients": n // 2},
                       partition_axes=axes, mesh=pod_mesh)
        def f(x):
            y = drjax.broadcast(x)
            z = drjax.map_fn(lambda a: a * 2.0, y)
            partial = drjax.reduce_mean(z, placement="clients")
            return drjax.reduce_mean(partial, placement="pods")

        x = jnp.ones((64,), jnp.float32)
        with compat.set_mesh(pod_mesh):
            lowered = jax.jit(f).lower(x)
            out = jax.jit(f)(x)
        np.testing.assert_allclose(np.asarray(out), 2.0 * np.ones(64))
        print(json.dumps({
            "ok": True,
            "has_sharding": "sharding" in lowered.as_text(),
            "replicated": bool(out.sharding.is_fully_replicated),
        }))
        """,
    )
    assert res["ok"] and res["has_sharding"] and res["replicated"]


@pytest.mark.slow
def test_flat_hierarchical_reduce_under_mesh(device_pool):
    """Regression: the flat-API hierarchical_reduce_mean must not pin its
    derived pods level to a mesh axis its P partials cannot shard over
    (P=2 pod partials over an 8-way data axis -> the level stays logical)."""
    res = _run(
        device_pool,
        """
        n = jax.device_count()

        @drjax.program(partition_size=2 * n, partition_axes="data", mesh=mesh)
        def f(xs):
            z = drjax.map_fn(lambda a: a * 2.0, xs)
            return drjax.hierarchical_reduce_mean(z, num_supergroups=2)

        xs = jnp.arange(2 * n, dtype=jnp.float32)
        with compat.set_mesh(mesh):
            out = jax.jit(f)(xs)
        np.testing.assert_allclose(
            np.asarray(out), 2.0 * np.asarray(xs).mean(), rtol=1e-6
        )
        g = jax.jit(jax.grad(lambda v: f(jnp.broadcast_to(v, (2 * n,)))))
        with compat.set_mesh(mesh):
            gv = g(jnp.float32(1.0))
        print(json.dumps({"ok": True, "grad": float(gv)}))
        """,
    )
    assert res["ok"] and abs(res["grad"] - 2.0) < 1e-5


@pytest.mark.slow
def test_spmd_axis_name_annotates_map_intermediates(device_pool):
    """map_fn must pass spmd_axis_name so intermediates carry the data axis."""
    res = _run(
        device_pool,
        """
        @drjax.program(partition_size=8, partition_axes="data", mesh=mesh)
        def f(x):
            y = drjax.broadcast(x)
            z = drjax.map_fn(lambda a: jnp.sin(a) * jnp.cos(a), y)
            return z

        x = jnp.ones((64,), jnp.float32)
        with compat.set_mesh(mesh):
            lowered = jax.jit(f).lower(x)
        txt = lowered.as_text()
        print(json.dumps({"has_sharding": "sharding" in txt}))
        """,
    )
    assert res["has_sharding"]
