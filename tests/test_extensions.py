"""Tests for the beyond-paper extensions: hierarchical reductions and
asynchronous (one-round-stale) local SGD."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core as drjax
from repro import optim
from repro.algorithms.async_rounds import make_async_local_sgd_round
from repro.algorithms.rounds import LocalSGDConfig, make_local_sgd_round
from repro.compression import int8_roundtrip
from repro.core.hierarchical import cross_pod_bytes, hierarchical_reduce_mean
from repro.data.grouped import CohortSampler, GroupedCorpus
from repro.models import registry


class TestHierarchicalReduce:
    def test_equals_flat_mean(self):
        @drjax.program(partition_size=8)
        def f(xs):
            return hierarchical_reduce_mean(xs, num_supergroups=2)

        xs = jnp.arange(8, dtype=jnp.float32)
        np.testing.assert_allclose(f(xs), xs.mean(), rtol=1e-6)

    def test_pytree_and_matrix(self):
        @drjax.program(partition_size=6)
        def f(tree):
            return hierarchical_reduce_mean(tree, num_supergroups=3)

        tree = {"w": jnp.arange(24, dtype=jnp.float32).reshape(6, 4)}
        out = f(tree)
        np.testing.assert_allclose(out["w"], tree["w"].mean(0), rtol=1e-6)

    def test_compressed_cross_pod_leg(self):
        @drjax.program(partition_size=8)
        def f(xs):
            return hierarchical_reduce_mean(
                xs, num_supergroups=2, compress_fn=int8_roundtrip
            )

        xs = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
        out = f(xs)
        ref = xs.mean(0)
        cos = float(
            (np.asarray(out).ravel() @ np.asarray(ref).ravel())
            / (np.linalg.norm(out) * np.linalg.norm(ref))
        )
        assert cos > 0.999

    def test_differentiable(self):
        """MapReduce AD flows through both stages."""

        @drjax.program(partition_size=4)
        def f(x):
            y = drjax.broadcast(x)
            z = drjax.map_fn(lambda a: a * a, y)
            return hierarchical_reduce_mean(z, num_supergroups=2)

        g = jax.grad(f)(jnp.float32(3.0))
        np.testing.assert_allclose(g, 6.0, rtol=1e-6)

    def test_indivisible_raises(self):
        @drjax.program(partition_size=6)
        def f(xs):
            return hierarchical_reduce_mean(xs, num_supergroups=4)

        with pytest.raises(ValueError, match="must divide"):
            f(jnp.zeros((6,)))

    def test_cross_pod_byte_model(self):
        m = cross_pod_bytes(16e9, n=512, num_supergroups=2,
                            compress_ratio=0.25)
        # 512 flat contributions -> 2 compressed partials: 1024x fewer bytes
        assert m["reduction_factor"] == pytest.approx(1024.0)


class TestAsyncLocalSGD:
    def _setup(self):
        cfg = registry.get_config("lm_350m").reduced()
        loss_fn = functools.partial(registry.loss_fn, cfg)
        params = registry.init_params(jax.random.PRNGKey(0), cfg)
        corpus = GroupedCorpus(vocab_size=cfg.vocab_size, num_groups=64)
        sampler = CohortSampler(corpus, cohort_size=4)
        return cfg, loss_fn, params, sampler

    def test_async_round_trains(self):
        cfg, loss_fn, params, sampler = self._setup()
        rc = LocalSGDConfig(partition_size=4, num_local_steps=2)
        server = optim.fedavg_momentum(1.0)
        round_fn, init_pending = make_async_local_sgd_round(
            loss_fn, optim.sgd(0.05), server, rc
        )
        round_fn = jax.jit(round_fn)
        pending = init_pending(params)
        sstate = server.init(params)
        losses = []
        for r in range(8):
            d = sampler.round_batch(r, 2, 2, 16)
            batch = {"tokens": d["tokens"], "labels": d["labels"]}
            params, pending, sstate, m = round_fn(params, pending, sstate,
                                                  batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0]

    def test_async_tracks_sync_closely(self):
        """One-round staleness should land near the synchronous trajectory."""
        cfg, loss_fn, params, sampler = self._setup()
        rc = LocalSGDConfig(partition_size=4, num_local_steps=2)

        sync = jax.jit(make_local_sgd_round(
            loss_fn, optim.sgd(0.05), optim.fedavg_momentum(1.0), rc))
        s_params = params
        s_state = optim.fedavg_momentum(1.0).init(params)

        a_round, init_pending = make_async_local_sgd_round(
            loss_fn, optim.sgd(0.05), optim.fedavg_momentum(1.0), rc)
        a_round = jax.jit(a_round)
        a_params, pending = params, init_pending(params)
        a_state = optim.fedavg_momentum(1.0).init(params)

        s_losses, a_losses = [], []
        for r in range(10):
            d = sampler.round_batch(r, 2, 2, 16)
            batch = {"tokens": d["tokens"], "labels": d["labels"]}
            s_params, s_state, sm = sync(s_params, s_state, batch)
            a_params, pending, a_state, am = a_round(a_params, pending,
                                                     a_state, batch)
            s_losses.append(float(sm["loss"]))
            a_losses.append(float(am["loss"]))
        # both trajectories improve and end within a small gap
        assert a_losses[-1] < a_losses[0]
        assert abs(a_losses[-1] - s_losses[-1]) < 0.35

    def test_reduce_is_independent_of_next_apply(self):
        """The overlap claim, structurally: in the jaxpr the reduce of this
        round's deltas does not feed this round's params output."""
        cfg, loss_fn, params, sampler = self._setup()
        rc = LocalSGDConfig(partition_size=2, num_local_steps=1)
        round_fn, init_pending = make_async_local_sgd_round(
            loss_fn, optim.sgd(0.05), optim.fedavg_momentum(1.0), rc)
        d = sampler.round_batch(0, 1, 1, 16)
        batch = {"tokens": d["tokens"][:2], "labels": d["labels"][:2]}
        pending = init_pending(params)
        sstate = optim.fedavg_momentum(1.0).init(params)
        out_params, new_pending, _, _ = round_fn(params, pending, sstate,
                                                 batch)
        # params update uses only the OLD pending delta
        expect = jax.tree_util.tree_map(
            lambda p, dlt: (p.astype(jnp.float32) + dlt).astype(p.dtype),
            params, pending)
        for a, b in zip(jax.tree_util.tree_leaves(out_params),
                        jax.tree_util.tree_leaves(expect)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), rtol=1e-5)
