"""Tests for the beyond-paper extensions: hierarchical reductions and
asynchronous (one-round-stale) local SGD."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core as drjax
from repro import optim
from repro.algorithms.async_rounds import make_async_local_sgd_round
from repro.algorithms.rounds import LocalSGDConfig, make_local_sgd_round
from repro.compression import int8_roundtrip
from repro.core.hierarchical import cross_pod_bytes, hierarchical_reduce_mean
from repro.data.grouped import CohortSampler, GroupedCorpus
from repro.models import registry


class TestHierarchicalReduce:
    def test_equals_flat_mean(self):
        @drjax.program(partition_size=8)
        def f(xs):
            return hierarchical_reduce_mean(xs, num_supergroups=2)

        xs = jnp.arange(8, dtype=jnp.float32)
        np.testing.assert_allclose(f(xs), xs.mean(), rtol=1e-6)

    def test_pytree_and_matrix(self):
        @drjax.program(partition_size=6)
        def f(tree):
            return hierarchical_reduce_mean(tree, num_supergroups=3)

        tree = {"w": jnp.arange(24, dtype=jnp.float32).reshape(6, 4)}
        out = f(tree)
        np.testing.assert_allclose(out["w"], tree["w"].mean(0), rtol=1e-6)

    def test_compressed_cross_pod_leg(self):
        @drjax.program(partition_size=8)
        def f(xs):
            return hierarchical_reduce_mean(
                xs, num_supergroups=2, compress_fn=int8_roundtrip
            )

        xs = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
        out = f(xs)
        ref = xs.mean(0)
        cos = float(
            (np.asarray(out).ravel() @ np.asarray(ref).ravel())
            / (np.linalg.norm(out) * np.linalg.norm(ref))
        )
        assert cos > 0.999

    def test_differentiable(self):
        """MapReduce AD flows through both stages."""

        @drjax.program(partition_size=4)
        def f(x):
            y = drjax.broadcast(x)
            z = drjax.map_fn(lambda a: a * a, y)
            return hierarchical_reduce_mean(z, num_supergroups=2)

        g = jax.grad(f)(jnp.float32(3.0))
        np.testing.assert_allclose(g, 6.0, rtol=1e-6)

    def test_indivisible_raises(self):
        @drjax.program(partition_size=6)
        def f(xs):
            return hierarchical_reduce_mean(xs, num_supergroups=4)

        with pytest.raises(ValueError, match="must divide"):
            f(jnp.zeros((6,)))

    def test_cross_pod_byte_model(self):
        m = cross_pod_bytes(16e9, n=512, num_supergroups=2,
                            compress_ratio=0.25)
        # 512 flat contributions -> 2 compressed partials: 1024x fewer bytes
        assert m["reduction_factor"] == pytest.approx(1024.0)

    def test_output_dtype_matches_flat_reduce_mean(self):
        """bf16 in -> bf16 out, exactly like flat reduce_mean (no silent
        f32 upcast escaping the hierarchical reduction)."""

        @drjax.program(partition_size=8)
        def hier(xs):
            return hierarchical_reduce_mean(xs, num_supergroups=2)

        @drjax.program(partition_size=8)
        def flat(xs):
            return drjax.reduce_mean(xs)

        xs = jnp.arange(8, dtype=jnp.bfloat16)
        out_h, out_f = hier(xs), flat(xs)
        assert out_h.dtype == out_f.dtype == jnp.bfloat16
        np.testing.assert_allclose(
            np.asarray(out_h, np.float32), np.asarray(out_f, np.float32),
            rtol=1e-2,
        )


class TestZeroWeightReductions:
    """All weights zero (every straggler dropped) must not produce NaN."""

    def test_masked_reduce_mean_all_dropped_returns_zeros(self):
        @drjax.program(partition_size=4)
        def f(xs, mask):
            return drjax.masked_reduce_mean(xs, mask)

        xs = jnp.arange(4, dtype=jnp.float32) + 1.0
        out = f(xs, jnp.zeros((4,), jnp.float32))
        assert np.all(np.isfinite(np.asarray(out)))
        np.testing.assert_array_equal(np.asarray(out), 0.0)

    def test_weighted_mean_all_zero_weights_pytree(self):
        @drjax.program(partition_size=3)
        def f(tree, w):
            return drjax.reduce_weighted_mean(tree, w)

        tree = {"a": jnp.ones((3, 2)), "b": jnp.arange(3, dtype=jnp.float32)}
        out = f(tree, jnp.zeros((3,)))
        for leaf in jax.tree_util.tree_leaves(out):
            np.testing.assert_array_equal(np.asarray(leaf), 0.0)

    def test_nonzero_weights_unchanged(self):
        @drjax.program(partition_size=4)
        def f(xs, mask):
            return drjax.masked_reduce_mean(xs, mask)

        xs = jnp.arange(4, dtype=jnp.float32)
        mask = jnp.array([1, 0, 1, 0], jnp.float32)
        np.testing.assert_allclose(f(xs, mask), (0.0 + 2.0) / 2.0)

    def test_gradient_finite_at_zero_mask(self):
        """MapReduce AD through the guarded reduction stays NaN-free."""

        @drjax.program(partition_size=4)
        def f(x, mask):
            xs = drjax.map_fn(lambda a: a * a, drjax.broadcast(x))
            return drjax.masked_reduce_mean(xs, mask)

        g = jax.grad(f)(jnp.float32(3.0), jnp.zeros((4,), jnp.float32))
        assert np.isfinite(float(g))

    def test_round_with_all_stragglers_dropped_keeps_params_finite(self):
        from repro.algorithms.rounds import make_local_sgd_round

        def loss_fn(params, batch):
            return jnp.mean((params["w"] * batch["x"] - batch["y"]) ** 2)

        cfg = LocalSGDConfig(
            partition_size=2, num_local_steps=1, straggler_mask=True
        )
        server = optim.fedavg_momentum(1.0)
        round_fn = make_local_sgd_round(
            loss_fn, optim.sgd(0.05), server, cfg
        )
        params = {"w": jnp.float32(1.0)}
        data = {
            "x": jnp.ones((2, 1, 4), jnp.float32),
            "y": jnp.ones((2, 1, 4), jnp.float32),
        }
        new_params, _, _ = round_fn(
            params, server.init(params), data, jnp.zeros((2,), jnp.float32)
        )
        # nothing arrived: params unchanged, not NaN-poisoned
        np.testing.assert_allclose(float(new_params["w"]), 1.0)


class TestAsyncLocalSGD:
    def _setup(self):
        cfg = registry.get_config("lm_350m").reduced()
        loss_fn = functools.partial(registry.loss_fn, cfg)
        params = registry.init_params(jax.random.PRNGKey(0), cfg)
        corpus = GroupedCorpus(vocab_size=cfg.vocab_size, num_groups=64)
        sampler = CohortSampler(corpus, cohort_size=4)
        return cfg, loss_fn, params, sampler

    def test_async_round_trains(self):
        cfg, loss_fn, params, sampler = self._setup()
        rc = LocalSGDConfig(partition_size=4, num_local_steps=2)
        server = optim.fedavg_momentum(1.0)
        round_fn, init_pending = make_async_local_sgd_round(
            loss_fn, optim.sgd(0.05), server, rc
        )
        round_fn = jax.jit(round_fn)
        pending = init_pending(params)
        sstate = server.init(params)
        losses = []
        for r in range(8):
            d = sampler.round_batch(r, 2, 2, 16)
            batch = {"tokens": d["tokens"], "labels": d["labels"]}
            params, pending, sstate, m = round_fn(params, pending, sstate,
                                                  batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0]

    def test_async_tracks_sync_closely(self):
        """One-round staleness should land near the synchronous trajectory."""
        cfg, loss_fn, params, sampler = self._setup()
        rc = LocalSGDConfig(partition_size=4, num_local_steps=2)

        sync = jax.jit(make_local_sgd_round(
            loss_fn, optim.sgd(0.05), optim.fedavg_momentum(1.0), rc))
        s_params = params
        s_state = optim.fedavg_momentum(1.0).init(params)

        a_round, init_pending = make_async_local_sgd_round(
            loss_fn, optim.sgd(0.05), optim.fedavg_momentum(1.0), rc)
        a_round = jax.jit(a_round)
        a_params, pending = params, init_pending(params)
        a_state = optim.fedavg_momentum(1.0).init(params)

        s_losses, a_losses = [], []
        for r in range(10):
            d = sampler.round_batch(r, 2, 2, 16)
            batch = {"tokens": d["tokens"], "labels": d["labels"]}
            s_params, s_state, sm = sync(s_params, s_state, batch)
            a_params, pending, a_state, am = a_round(a_params, pending,
                                                     a_state, batch)
            s_losses.append(float(sm["loss"]))
            a_losses.append(float(am["loss"]))
        # both trajectories improve and end within a small gap
        assert a_losses[-1] < a_losses[0]
        assert abs(a_losses[-1] - s_losses[-1]) < 0.35

    def test_init_pending_preserves_dtype(self):
        """bf16 params must get bf16 pending deltas (no forced float32)."""

        def tiny_loss(p, batch):
            return jnp.mean((p["w"] * batch["x"] - batch["y"]) ** 2)

        rc = LocalSGDConfig(partition_size=2, num_local_steps=1)
        _, init_pending = make_async_local_sgd_round(
            tiny_loss, optim.sgd(0.05), optim.fedavg_momentum(1.0), rc
        )
        params = {
            "w": jnp.ones((3,), jnp.bfloat16),
            "b": jnp.zeros((), jnp.float32),
        }
        pending = init_pending(params)
        assert pending["w"].dtype == jnp.bfloat16
        assert pending["b"].dtype == jnp.float32
        for leaf in jax.tree_util.tree_leaves(pending):
            np.testing.assert_array_equal(np.asarray(leaf, np.float32), 0.0)

    def test_bf16_async_round_trip(self):
        """A bf16-param async round runs end to end with dtypes preserved."""

        def tiny_loss(p, batch):
            pred = (p["w"].astype(jnp.float32) * batch["x"]).sum(-1)
            return jnp.mean((pred - batch["y"]) ** 2)

        rc = LocalSGDConfig(partition_size=2, num_local_steps=1)
        server = optim.fedavg_momentum(1.0)
        round_fn, init_pending = make_async_local_sgd_round(
            tiny_loss, optim.sgd(0.05), server, rc
        )
        params = {"w": jnp.ones((4,), jnp.bfloat16)}
        pending = init_pending(params)
        sstate = server.init(params)
        data = {
            "x": jnp.ones((2, 1, 8, 4), jnp.float32),
            "y": jnp.zeros((2, 1, 8), jnp.float32),
        }
        for _ in range(2):
            params, pending, sstate, m = round_fn(
                params, pending, sstate, data
            )
        assert params["w"].dtype == jnp.bfloat16
        assert np.all(np.isfinite(np.asarray(params["w"], np.float32)))
        assert np.isfinite(float(m["loss"]))

    def test_reduce_is_independent_of_next_apply(self):
        """The overlap claim, structurally: in the jaxpr the reduce of this
        round's deltas does not feed this round's params output."""
        cfg, loss_fn, params, sampler = self._setup()
        rc = LocalSGDConfig(partition_size=2, num_local_steps=1)
        round_fn, init_pending = make_async_local_sgd_round(
            loss_fn, optim.sgd(0.05), optim.fedavg_momentum(1.0), rc)
        d = sampler.round_batch(0, 1, 1, 16)
        batch = {"tokens": d["tokens"][:2], "labels": d["labels"][:2]}
        pending = init_pending(params)
        sstate = optim.fedavg_momentum(1.0).init(params)
        out_params, new_pending, _, _ = round_fn(params, pending, sstate,
                                                 batch)
        # params update uses only the OLD pending delta
        expect = jax.tree_util.tree_map(
            lambda p, dlt: (p.astype(jnp.float32) + dlt).astype(p.dtype),
            params, pending)
        for a, b in zip(jax.tree_util.tree_leaves(out_params),
                        jax.tree_util.tree_leaves(expect)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), rtol=1e-5)
