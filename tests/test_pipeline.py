"""Pipeline-stage placements: stage_transfer/stage_map semantics, the 1F1B
microbatch lowering, and the analysis passes' stage-kind awareness.

Acceptance bar (ISSUE 7): a pipelined program (>= 2 stages, >= 4
microbatches) built by ``make_pipelined_round`` compiles to ONE
donation-aware executable that is bitwise-equal to ``run_plan`` on CPU,
holds the zero-retrace invariant, and analyzes clean via ``plan.analyze()``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core as drjax
from repro.algorithms import (
    PipelineConfig,
    make_pipelined_round,
    pipeline_bubble_fraction,
)
from repro.analysis import commcost, placement_safety
from repro.core import interpreter as interp
from repro.core import placement as placement_lib
from repro.core import primitives as prims
from repro.runtime.executor import compile_plan


def stage_ctx(num_stages=3, clients=4):
    return placement_lib.make_context(
        None,
        placements={"stages": num_stages, "clients": clients},
        placement_kinds={"stages": "stages"},
    )


# ---------------------------------------------------------------------------
# placement kinds
# ---------------------------------------------------------------------------


class TestPlacementKinds:
    def test_default_kind_is_replicas(self):
        ctx = placement_lib.make_context(None, placements={"clients": 4})
        assert ctx.kinds == ("replicas",)
        assert ctx.stage_names() == ()

    def test_stage_kind_recorded(self):
        ctx = stage_ctx()
        assert ctx.kinds == ("stages", "replicas")
        assert ctx.stage_names() == ("stages",)

    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError, match="kind"):
            placement_lib.Placement("p", 2, None, kind="banana")

    def test_unknown_placement_name_in_kinds_rejected(self):
        with pytest.raises(ValueError):
            placement_lib.make_context(
                None,
                placements={"clients": 4},
                placement_kinds={"nope": "stages"},
            )


# ---------------------------------------------------------------------------
# stage_transfer semantics
# ---------------------------------------------------------------------------


class TestStageTransfer:
    def test_forward_shift_zero_fills_entry(self):
        ctx = stage_ctx(3, 4)
        with drjax.placement_context(ctx):
            x = jnp.arange(12, dtype=jnp.float32).reshape(3, 4)
            y = drjax.stage_transfer(x)
        np.testing.assert_array_equal(np.asarray(y)[0], np.zeros(4))
        np.testing.assert_array_equal(np.asarray(y)[1:], np.asarray(x)[:2])

    def test_negative_shift(self):
        ctx = stage_ctx(3, 4)
        with drjax.placement_context(ctx):
            x = jnp.arange(12, dtype=jnp.float32).reshape(3, 4)
            y = drjax.stage_transfer(x, shift=-1)
        np.testing.assert_array_equal(np.asarray(y)[:2], np.asarray(x)[1:])
        np.testing.assert_array_equal(np.asarray(y)[2], np.zeros(4))

    def test_wrap_is_roll(self):
        ctx = stage_ctx(3, 4)
        with drjax.placement_context(ctx):
            x = jnp.arange(12, dtype=jnp.float32).reshape(3, 4)
            y = drjax.stage_transfer(x, wrap=True)
        np.testing.assert_array_equal(
            np.asarray(y), np.roll(np.asarray(x), 1, axis=0)
        )

    def test_oversized_shift_zeroes_everything(self):
        ctx = stage_ctx(3, 4)
        with drjax.placement_context(ctx):
            x = jnp.ones((3, 4), jnp.float32)
            y = drjax.stage_transfer(x, shift=5)
        np.testing.assert_array_equal(np.asarray(y), np.zeros((3, 4)))

    def test_transpose_is_reverse_transfer(self):
        """Linear primitive: grad of sum(transfer(x, +1)) must equal
        transfer(ones, -1) — the backward pipeline falls out of AD."""
        ctx = stage_ctx(3, 4)
        with drjax.placement_context(ctx):
            x = jnp.arange(12, dtype=jnp.float32).reshape(3, 4)
            g = jax.grad(
                lambda v: jnp.sum(drjax.stage_transfer(v) ** 2)
            )(x)
            fwd = drjax.stage_transfer(x)
            expect = drjax.stage_transfer(
                jax.tree_util.tree_map(lambda v: 2.0 * v, fwd), shift=-1
            )
        np.testing.assert_array_equal(np.asarray(g), np.asarray(expect))

    def test_tree_polymorphic(self):
        ctx = stage_ctx(3, 4)
        with drjax.placement_context(ctx):
            tree = {"a": jnp.ones((3, 4)), "b": jnp.zeros((3, 4, 2))}
            out = drjax.stage_transfer(tree)
        assert set(out) == {"a", "b"}
        np.testing.assert_array_equal(np.asarray(out["a"][0]), np.zeros(4))

    def test_batching_rule(self):
        ctx = stage_ctx(3, 4)
        with drjax.placement_context(ctx):
            xs = jnp.arange(24, dtype=jnp.float32).reshape(2, 3, 4)
            out = jax.vmap(lambda v: drjax.stage_transfer(v))(xs)
            per = jnp.stack([
                drjax.stage_transfer(xs[0]), drjax.stage_transfer(xs[1]),
            ])
        np.testing.assert_array_equal(np.asarray(out), np.asarray(per))

    def test_requires_stage_kind_placement(self):
        ctx = placement_lib.make_context(None, placements={"clients": 4})
        with drjax.placement_context(ctx):
            with pytest.raises(ValueError, match="stage"):
                drjax.stage_transfer(jnp.ones((4, 2)))

    def test_explicit_replica_placement_rejected(self):
        ctx = stage_ctx(3, 4)
        with drjax.placement_context(ctx):
            with pytest.raises(ValueError, match="kind"):
                drjax.stage_transfer(
                    jnp.ones((3, 4)), placement="clients"
                )

    def test_bind_rejects_replica_kind_at_abstract_eval(self):
        ctx = placement_lib.make_context(None, placements={"clients": 4})
        with drjax.placement_context(ctx):
            with pytest.raises(ValueError):
                prims.bind_stage_transfer(
                    jnp.ones((4, 2)), placement="clients"
                )


class TestWrongKindCollectives:
    def test_broadcast_at_stage_level_rejected(self):
        ctx = stage_ctx()
        with drjax.placement_context(ctx):
            with pytest.raises(ValueError, match="replicas"):
                drjax.broadcast(jnp.ones(()), placement="stages")

    def test_reduce_at_stage_level_rejected(self):
        ctx = stage_ctx()
        with drjax.placement_context(ctx):
            with pytest.raises(ValueError, match="replicas"):
                drjax.reduce_sum(
                    jnp.ones((3, 4)), placement="stages"
                )

    def test_default_span_collectives_guarded(self):
        ctx = stage_ctx()
        with drjax.placement_context(ctx):
            with pytest.raises(ValueError, match="stage_transfer"):
                drjax.broadcast(jnp.ones(()))
            with pytest.raises(ValueError, match="stage_transfer"):
                drjax.reduce_mean(jnp.ones((3, 4)))
            with pytest.raises(ValueError, match="stage_transfer"):
                drjax.reduce_weighted_mean(
                    jnp.ones((3, 4)), jnp.ones((3, 4))
                )

    def test_replica_level_still_works(self):
        ctx = stage_ctx(3, 4)
        with drjax.placement_context(ctx):
            out = drjax.reduce_sum(jnp.ones((3, 4)), placement="clients")
        assert out.shape == (3,)
        np.testing.assert_array_equal(np.asarray(out), 4.0 * np.ones(3))


# ---------------------------------------------------------------------------
# stage_map
# ---------------------------------------------------------------------------


class TestStageMap:
    def test_single_callable_is_map_fn(self):
        ctx = stage_ctx(3, 4)
        with drjax.placement_context(ctx):
            x = jnp.arange(12, dtype=jnp.float32).reshape(3, 4)
            a = drjax.stage_map(lambda v: v * 2.0, x)
            b = drjax.map_fn(lambda v: v * 2.0, x, placement="stages")
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_heterogeneous_stage_functions(self):
        ctx = stage_ctx(3, 4)
        with drjax.placement_context(ctx):
            x = jnp.ones((3, 4), jnp.float32)
            out = drjax.stage_map(
                [lambda v: v + 1.0, lambda v: v * 3.0, lambda v: v - 2.0], x
            )
        expect = np.stack([
            np.full(4, 2.0), np.full(4, 3.0), np.full(4, -1.0),
        ])
        np.testing.assert_array_equal(np.asarray(out), expect)

    def test_wrong_function_count_rejected(self):
        ctx = stage_ctx(3, 4)
        with drjax.placement_context(ctx):
            with pytest.raises(ValueError, match="3 stages"):
                drjax.stage_map(
                    [lambda v: v, lambda v: v], jnp.ones((3, 4))
                )

    def test_tuple_tree_positional_args(self):
        ctx = stage_ctx(2, 4)
        with drjax.placement_context(ctx):
            a = jnp.ones((2, 4))
            b = 2.0 * jnp.ones((2, 4))
            out = drjax.stage_map(
                [lambda u, v: u + v, lambda u, v: u * v], (a, b)
            )
        expect = np.stack([np.full(4, 3.0), np.full(4, 2.0)])
        np.testing.assert_array_equal(np.asarray(out), expect)

    def test_outer_levels_stay_mapped(self):
        """A stage level nested INSIDE a replica level: the per-stage fns see
        one group's slice (outer axes vmapped away)."""
        ctx = placement_lib.make_context(
            None,
            placements={"pods": 2, "stages": 3},
            placement_kinds={"stages": "stages"},
        )
        with drjax.placement_context(ctx):
            x = jnp.arange(2 * 3 * 4, dtype=jnp.float32).reshape(2, 3, 4)
            out = drjax.stage_map(
                [lambda v: v + 1.0, lambda v: v * 2.0, lambda v: v - 1.0], x
            )
        xs = np.asarray(x)
        expect = np.stack(
            [xs[:, 0] + 1.0, xs[:, 1] * 2.0, xs[:, 2] - 1.0], axis=1
        )
        np.testing.assert_array_equal(np.asarray(out), expect)


# ---------------------------------------------------------------------------
# the 1F1B pipelined round
# ---------------------------------------------------------------------------


def pipelined_setup(s=3, m=5, d=4, hetero=True):
    cfg = PipelineConfig(num_stages=s, num_microbatches=m)
    if hetero:
        fns = [
            (lambda k: (lambda a: a + float(k)))(k) for k in range(s)
        ]
    else:
        fns = lambda a: jnp.tanh(a)
    round_fn = make_pipelined_round(fns, cfg)
    mb = jnp.arange(m * d, dtype=jnp.float32).reshape(m, d) / (m * d)
    act0 = jnp.zeros((s, d), jnp.float32)
    return round_fn, mb, act0


class TestPipelinedRound:
    def test_outputs_match_sequential_composition(self):
        round_fn, mb, act0 = pipelined_setup(s=3, m=5)
        outs, act_final = round_fn(mb, act0)
        ref = np.asarray(mb) + 0.0 + 1.0 + 2.0  # the three phases composed
        np.testing.assert_array_equal(np.asarray(outs), ref)
        assert act_final.shape == act0.shape

    def test_bubble_fraction(self):
        assert pipeline_bubble_fraction(3, 5) == pytest.approx(2 / 7)
        assert pipeline_bubble_fraction(1, 8) == 0.0
        with pytest.raises(ValueError):
            pipeline_bubble_fraction(0, 4)

    def test_plan_has_transfer_inside_loop(self):
        round_fn, mb, act0 = pipelined_setup()
        plan = interp.build_plan(
            interp.trace(round_fn, mb, act0), round_fn.drjax_context,
            partitioned_invars=(0, 1),
        )
        kinds = [
            type(st).__name__ for _n, st, _o in plan.named_stages()
        ]
        assert "LoopStage" in kinds and "Transfer" in kinds
        text = plan.to_text()
        assert "TRANSFER shift=+1 @stages" in text
        assert "[stages]" in text  # header marks the stage-kind level

    def test_compiled_bitwise_and_zero_retrace(self):
        """The acceptance criterion: S>=2, M>=4, ONE executable, bitwise
        equal to run_plan, exactly one trace across repeated calls."""
        round_fn, mb, act0 = pipelined_setup(s=3, m=5)
        plan = interp.build_plan(
            interp.trace(round_fn, mb, act0), round_fn.drjax_context,
            partitioned_invars=(0, 1),
        )
        compiled = compile_plan(plan)
        ref = drjax.run_plan(plan, mb, act0)
        for _ in range(3):
            outs = compiled(mb, act0)
            for a, b in zip(outs, ref):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert compiled.trace_count == 1

    def test_plan_analyzes_clean(self):
        round_fn, mb, act0 = pipelined_setup(s=3, m=5)
        plan = interp.build_plan(
            interp.trace(round_fn, mb, act0), round_fn.drjax_context,
            partitioned_invars=(0, 1),
        )
        report = plan.analyze()
        assert not report.errors, report

    def test_donated_round_frees_activation_buffer(self):
        cfg = PipelineConfig(num_stages=2, num_microbatches=4)
        round_fn = make_pipelined_round(
            lambda a: a * 2.0, cfg, donate=True
        )
        mb = jnp.ones((4, 3), jnp.float32)
        act0 = jnp.zeros((2, 3), jnp.float32)
        outs, act_final = round_fn(mb, act0)
        assert act0.is_deleted()  # donated into the executable
        # and the next round can rebind the returned buffer
        outs2, _ = round_fn(mb, act_final)
        np.testing.assert_array_equal(np.asarray(outs), np.asarray(outs2))

    def test_grad_through_pipeline(self):
        """AD through scan + stage_map + stage_transfer: the gradient of a
        linear pipeline w.r.t. the microbatches is exact."""
        cfg = PipelineConfig(num_stages=2, num_microbatches=4)
        round_fn = make_pipelined_round(lambda a: 3.0 * a, cfg)
        act0 = jnp.zeros((2, 3), jnp.float32)

        def loss(mb):
            outs, _ = round_fn(mb, act0)
            return jnp.sum(outs)

        mb = jnp.ones((4, 3), jnp.float32)
        g = jax.grad(loss)(mb)
        # each microbatch passes through both stages: d(sum)/d(mb) = 9
        np.testing.assert_array_equal(
            np.asarray(g), 9.0 * np.ones((4, 3))
        )

    def test_single_stage_degenerate(self):
        cfg = PipelineConfig(num_stages=1, num_microbatches=4)
        round_fn = make_pipelined_round(lambda a: a + 1.0, cfg)
        mb = jnp.arange(12, dtype=jnp.float32).reshape(4, 3)
        act0 = jnp.zeros((1, 3), jnp.float32)
        outs, _ = round_fn(mb, act0)
        np.testing.assert_array_equal(
            np.asarray(outs), np.asarray(mb) + 1.0
        )


# ---------------------------------------------------------------------------
# analysis passes
# ---------------------------------------------------------------------------


class TestPipelineAnalysis:
    def _plan(self, s=2, m=4, d=8):
        round_fn, mb, act0 = pipelined_setup(s=s, m=m, d=d)
        return interp.build_plan(
            interp.trace(round_fn, mb, act0), round_fn.drjax_context,
            partitioned_invars=(0, 1),
        )

    def test_commcost_prices_transfer_as_ici(self):
        plan = self._plan(s=2, m=4, d=8)
        rep = commcost.estimate_comm_cost(plan)
        transfers = [c for c in rep.per_stage if c.kind == "TRANSFER"]
        assert transfers, rep.per_stage
        c = transfers[0]
        assert c.link == "ici" and c.op == "stage_transfer"
        # 2 stages, shift 1, non-wrap: one sender; payload = 8 f32 = 32 B;
        # multiplied by the scan trip count M + S - 1 = 5.
        assert c.endpoints == 1
        assert c.payload_bytes == 32.0
        assert c.multiplier == 5.0
        assert rep.ici_bytes == 160.0 and rep.dcn_bytes == 0.0

    def test_commcost_wrap_counts_every_stage(self):
        ctx = stage_ctx(4, 1)

        def f(x):
            return drjax.stage_transfer(x, wrap=True)

        f.drjax_context = ctx
        with drjax.placement_context(ctx):
            x = jnp.ones((4, 1, 8), jnp.float32)
            plan = interp.build_plan(interp.trace(f, x), ctx)
        rep = commcost.estimate_comm_cost(plan)
        (c,) = [c for c in rep.per_stage if c.kind == "TRANSFER"]
        assert c.endpoints == 4  # ring: no idle boundary stage

    def test_wrong_kind_transfer_finding(self):
        """A transfer whose eqn context says the level is replica-kind (a
        hand-mutated plan — abstract eval blocks tracing one) is an error."""
        plan = self._plan()
        transfers = [
            st for _n, st, _o in plan.named_stages()
            if isinstance(st, interp.Transfer)
        ]
        repl = placement_lib.make_context(None, placements={"stages": 2})
        transfers[0].eqn.params["pctx"] = repl
        found = placement_safety.check_placement_safety(plan)
        assert any(
            f.code == "placement/wrong-kind-comm" and f.severity == "error"
            for f in found
        ), found

    def test_wrong_kind_reduce_finding(self):
        """A reduce addressing a stage-kind level (same mutation trick)."""
        ctx = placement_lib.make_context(None, placements={"clients": 4})

        def f(x):
            return drjax.reduce_sum(x)

        f.drjax_context = ctx
        with drjax.placement_context(ctx):
            x = jnp.ones((4, 2), jnp.float32)
            plan = interp.build_plan(interp.trace(f, x), ctx)
        reduces = [
            st for _n, st, _o in plan.named_stages()
            if isinstance(st, interp.Reduce)
        ]
        staged = placement_lib.make_context(
            None, placements={"clients": 4},
            placement_kinds={"clients": "stages"},
        )
        reduces[0].eqn.params["pctx"] = staged
        found = placement_safety.check_placement_safety(plan)
        assert any(
            f.code == "placement/wrong-kind-comm" for f in found
        ), found

    def test_transfer_stages_in_beam_text(self):
        """The Beam emitter stages a Transfer (rekey + boundary zero-fill);
        the emitted pipeline is valid Python like every other plan's."""
        round_fn, mb, act0 = pipelined_setup(s=2, m=4)
        plan = interp.build_plan(
            interp.trace(round_fn, mb, act0), round_fn.drjax_context,
            partitioned_invars=(0, 1),
        )
        text = plan.to_beam()
        compile(text, "<to_beam>", "exec")
        assert "Transfer" in text or "_stage_shift" in text, text
