"""Mesh-factorization regression tests (N-level placement stacks).

The refactor that generalized ``mesh_for_placements`` / ``placement_axes_for``
from the hard-coded ``(pod, data)`` pair to any ordered stack must leave the
legacy flat and 2-level outputs byte-identical — these tests pin them. The
old too-many-levels failure mode (3+ replica levels raised) is now the
supported ``(superpod, pod, data)`` factorization, exercised here up to a
full hierarchical round on the 8-fake-device worker mesh.

Axis-naming logic (``level_axes_for``) is pure string math and runs
in-process; anything that actually builds a mesh needs the device count to
match the placement product and runs in the shared device-pool worker.
"""

import textwrap

import pytest

from repro.core.placement import Placement, make_context
from repro.launch.mesh import level_axes_for, partition_axes_for

_PRELUDE = """
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro import compat
    from repro import core as drjax
    from repro.launch.mesh import (
        mesh_for_placements, partition_axes_for, placement_axes_for,
    )
"""


def _run(device_pool, body: str) -> dict:
    return device_pool.run(
        textwrap.dedent(_PRELUDE) + textwrap.dedent(body)
    )


class TestLevelAxes:
    """The naming rule: replica levels factorize innermost-out over
    (data, pod, superpod, repl4, ...); stage levels get stage, stage2, ..."""

    def test_legacy_flat(self):
        assert level_axes_for({"clients": 8}) == ("data",)

    def test_legacy_two_level(self):
        # Byte-identical to the historical hard-coded ("pod", "data") pair.
        assert level_axes_for({"pods": 2, "clients": 4}) == ("pod", "data")

    def test_three_level(self):
        assert level_axes_for(
            {"superpods": 2, "pods": 2, "clients": 2}
        ) == ("superpod", "pod", "data")

    def test_deeper_levels_generate_names(self):
        assert level_axes_for(
            {"a": 2, "b": 2, "c": 2, "d": 2}
        ) == ("repl4", "superpod", "pod", "data")

    def test_stage_level_owns_stage_axis(self):
        assert level_axes_for(
            [("stages", 4, "stages"), ("clients", 2)]
        ) == ("stage", "data")

    def test_two_stage_levels(self):
        assert level_axes_for(
            [("outer", 2, "stages"), ("inner", 2, "stages"), ("clients", 2)]
        ) == ("stage", "stage2", "data")

    def test_accepts_placement_context(self):
        ctx = make_context(
            None,
            placements={"stages": 2, "clients": 4},
            placement_kinds={"stages": "stages"},
        )
        assert level_axes_for(ctx) == ("stage", "data")

    def test_accepts_placement_objects(self):
        pls = (Placement("s", 2, None, kind="stages"), Placement("c", 4, None))
        assert level_axes_for(pls) == ("stage", "data")


class TestPartitionAxesFor:
    def test_none_mesh(self):
        assert partition_axes_for(None) is None


@pytest.mark.slow
class TestMeshForPlacements:
    def test_legacy_flat_identical(self, device_pool):
        res = _run(
            device_pool,
            """
            mesh = mesh_for_placements({"clients": jax.device_count()})
            print(json.dumps({
                "axes": list(mesh.axis_names),
                "shape": list(mesh.devices.shape),
            }))
            """,
        )
        n = device_pool.num_devices
        assert res == {"axes": ["data"], "shape": [n]}

    def test_legacy_two_level_identical(self, device_pool):
        res = _run(
            device_pool,
            """
            n = jax.device_count()
            mesh = mesh_for_placements({"pods": 2, "clients": n // 2})
            paxes = placement_axes_for(mesh)
            paxes_explicit = placement_axes_for(
                mesh, {"pods": 2, "clients": n // 2}
            )
            print(json.dumps({
                "axes": list(mesh.axis_names),
                "shape": list(mesh.devices.shape),
                "partition": list(partition_axes_for(mesh)),
                "paxes": paxes,
                "paxes_explicit": paxes_explicit,
            }))
            """,
        )
        n = device_pool.num_devices
        assert res["axes"] == ["pod", "data"]
        assert res["shape"] == [2, n // 2]
        assert res["partition"] == ["pod", "data"]
        # Legacy default dict unchanged; the N-level path agrees on 2 levels.
        assert res["paxes"] == {"pods": "pod", "clients": "data"}
        assert res["paxes_explicit"] == {"pods": "pod", "clients": "data"}

    def test_legacy_model_axis_appended(self, device_pool):
        res = _run(
            device_pool,
            """
            n = jax.device_count()
            mesh = mesh_for_placements({"clients": n // 2}, model_parallel=2)
            print(json.dumps({
                "axes": list(mesh.axis_names),
                "shape": list(mesh.devices.shape),
            }))
            """,
        )
        n = device_pool.num_devices
        assert res == {"axes": ["data", "model"], "shape": [n // 2, 2]}

    def test_empty_placements_still_raises(self, device_pool):
        res = _run(
            device_pool,
            """
            try:
                mesh_for_placements({})
                print(json.dumps({"raised": False}))
            except ValueError as e:
                print(json.dumps({"raised": True, "msg": str(e)}))
            """,
        )
        assert res["raised"] and "empty" in res["msg"]

    def test_three_level_now_supported(self, device_pool):
        """The old >2-level ValueError path is now the supported N-level
        factorization."""
        if device_pool.num_devices % 8:
            pytest.skip("needs a device count divisible by 8")
        res = _run(
            device_pool,
            """
            n = jax.device_count()
            spec = {"superpods": 2, "pods": 2, "clients": n // 4}
            mesh = mesh_for_placements(spec)
            print(json.dumps({
                "axes": list(mesh.axis_names),
                "shape": list(mesh.devices.shape),
                "partition": list(partition_axes_for(mesh)),
                "paxes": placement_axes_for(mesh, spec),
            }))
            """,
        )
        n = device_pool.num_devices
        assert res["axes"] == ["superpod", "pod", "data"]
        assert res["shape"] == [2, 2, n // 4]
        assert res["partition"] == ["superpod", "pod", "data"]
        assert res["paxes"] == {
            "superpods": "superpod", "pods": "pod", "clients": "data",
        }

    def test_stage_level_mesh(self, device_pool):
        res = _run(
            device_pool,
            """
            n = jax.device_count()
            spec = [("stages", 2, "stages"), ("clients", n // 2)]
            mesh = mesh_for_placements(spec)
            print(json.dumps({
                "axes": list(mesh.axis_names),
                "shape": list(mesh.devices.shape),
                "paxes": placement_axes_for(mesh, spec),
            }))
            """,
        )
        n = device_pool.num_devices
        assert res["axes"] == ["stage", "data"]
        assert res["shape"] == [2, n // 2]
        assert res["paxes"] == {"stages": "stage", "clients": "data"}


@pytest.mark.slow
def test_three_level_hierarchical_round(device_pool):
    """Acceptance: a 3-level (superpod, pod, data) hierarchical round runs
    on the fake-device mesh, each level addressed explicitly, and computes
    the same answer as the unsharded reference."""
    if device_pool.num_devices % 8:
        pytest.skip("needs a device count divisible by 8")
    res = _run(
        device_pool,
        """
        n = jax.device_count()
        spec = {"superpods": 2, "pods": 2, "clients": n // 4}
        mesh = mesh_for_placements(spec)
        paxes = placement_axes_for(mesh, spec)

        @drjax.program(placements=spec, partition_axes=paxes, mesh=mesh)
        def f(x):
            y = drjax.broadcast(x)
            z = drjax.map_fn(lambda a: a * 2.0, y, placement="clients")
            p1 = drjax.reduce_mean(z, placement="clients")
            p2 = drjax.reduce_mean(p1, placement="pods")
            return drjax.reduce_mean(p2, placement="superpods")

        x = jnp.ones((32,), jnp.float32)
        with compat.set_mesh(mesh):
            out = jax.jit(f)(x)
        np.testing.assert_allclose(np.asarray(out), 2.0 * np.ones(32))
        print(json.dumps({
            "ok": True,
            "replicated": bool(out.sharding.is_fully_replicated),
        }))
        """,
    )
    assert res["ok"] and res["replicated"]
