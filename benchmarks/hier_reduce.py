"""Flat vs hierarchical reduce: step time + modeled cross-pod traffic.

Seeds the perf trajectory for the nested-placement work: measures the jitted
per-call wall time of a flat ``reduce_mean`` over n groups against the
two-stage ``hierarchical_reduce_mean`` (P pod partials), and pairs each
measurement with the :func:`repro.core.cross_pod_bytes` napkin model of the
bytes that would cross the slow DCN leg at production scale. On a single CPU
host the step times are near-identical (both lower to the same flops) — the
headline column is the modeled byte reduction, which is what the two-stage
form buys on a real multi-pod fabric.

Writes ``BENCH_hier.json`` next to the repo root (and prints the usual
benchmark CSV rows via :func:`run`).
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from repro import core as drjax

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(_REPO, "BENCH_hier.json")


def _time(fn, *args, iters: int = 30) -> float:
    out = fn(*args)  # warmup/compile
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def _bench_point(n: int, num_pods: int, d: int) -> dict:
    @drjax.program(partition_size=n)
    def flat(xs):
        return drjax.reduce_mean(xs)

    @drjax.program(partition_size=n)
    def hier(xs):
        return drjax.hierarchical_reduce_mean(xs, num_supergroups=num_pods)

    @drjax.program(placements={"pods": num_pods, "clients": n // num_pods})
    def nested(xs):
        return drjax.reduce_mean(xs)  # two primitives via the stack

    xs = jax.random.normal(jax.random.PRNGKey(0), (n, d), jnp.float32)
    xs_nested = xs.reshape(num_pods, n // num_pods, d)
    flat_us = _time(jax.jit(flat), xs) * 1e6
    hier_us = _time(jax.jit(hier), xs) * 1e6
    nested_us = _time(jax.jit(nested), xs_nested) * 1e6
    # Modeled DCN traffic for a production-sized delta (paper §6 scenario):
    # param_bytes is per-group contribution crossing the slow leg.
    param_bytes = xs.dtype.itemsize * d
    model = drjax.cross_pod_bytes(param_bytes, n=n, num_supergroups=num_pods)
    return {
        "n": n,
        "num_pods": num_pods,
        "payload_floats": d,
        "flat_us_per_call": flat_us,
        "hier_us_per_call": hier_us,
        "nested_stack_us_per_call": nested_us,
        "modeled_flat_dcn_bytes": model["flat_bytes"],
        "modeled_hier_dcn_bytes": model["hierarchical_bytes"],
        "modeled_dcn_reduction": model["reduction_factor"],
    }


def run():
    points = [
        _bench_point(64, 4, 1 << 14),
        _bench_point(256, 8, 1 << 12),
    ]
    with open(OUT_PATH, "w") as f:
        json.dump({"points": points}, f, indent=2)
    rows = []
    for pt in points:
        key = f"hier_reduce_n{pt['n']}_P{pt['num_pods']}"
        rows.append({
            "name": f"{key}_flat",
            "us_per_call": f"{pt['flat_us_per_call']:.1f}",
            "derived": f"dcn_bytes={pt['modeled_flat_dcn_bytes']:.0f}",
        })
        rows.append({
            "name": f"{key}_hier",
            "us_per_call": f"{pt['hier_us_per_call']:.1f}",
            "derived": (
                f"dcn_bytes={pt['modeled_hier_dcn_bytes']:.0f}; "
                f"dcn_reduction={pt['modeled_dcn_reduction']:.0f}x"
            ),
        })
        rows.append({
            "name": f"{key}_nested_stack",
            "us_per_call": f"{pt['nested_stack_us_per_call']:.1f}",
            "derived": "placements=pods/clients",
        })
    return rows


if __name__ == "__main__":
    for row in run():
        print(f"{row['name']},{row['us_per_call']},{row['derived']}")
    print(f"wrote {OUT_PATH}")
