"""Flat vs hierarchical vs FUSED hierarchical reduce: wall-clock + DCN model.

Measures the jitted per-call wall time of

* ``flat``     — one ``reduce_mean`` over n groups (the baseline every
  hierarchical variant must beat to be worth its complexity);
* ``hier``     — the PR-3 two-stage composition, uncompressed;
* ``nested``   — the same two stages bound via a genuine placement stack;
* ``unfused``  — two-stage with the int8 cross-pod compression as the
  generic reduce → quantize → dequantize chain (``use_fused=False``);
* ``fused``    — two-stage with the compression recognized and routed
  through the single-pass reduce+compress kernel path (the PR-4 fast path).

and pairs each point with the :func:`repro.core.cross_pod_bytes` napkin
model of the bytes crossing the slow DCN leg at production scale. The
headline claim is measured, not asserted: fused hierarchical must be ≤ flat
in wall-clock at these shapes *and* 16-32× cheaper in modeled DCN bytes.

``BENCH_hier.json`` is a per-PR **trajectory**: each run appends (or
replaces, for re-runs at the same commit) an entry keyed by the current git
SHA under ``"trajectory"``, and mirrors the latest points under ``"points"``
for quick reading. Invoked via ``benchmarks.run`` (key ``hier``).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import core as drjax
from repro.compression import int8_roundtrip
from repro.launch import bench_log

OUT_PATH = bench_log.bench_path()


def _time_interleaved(fns, args, iters: int = 30, reps: int = 5):
    """Best-of-reps per-call time for each fn, with the reps ROUND-ROBINED
    across fns so transient host load hits every variant equally (the
    fused-vs-flat ratio is the headline; absolute times on a shared CPU
    host are noisy)."""
    for fn in fns:
        jax.block_until_ready(fn(*args))  # warmup/compile
    best = [float("inf")] * len(fns)
    for _ in range(reps):
        for k, fn in enumerate(fns):
            t0 = time.perf_counter()
            for _ in range(iters):
                out = fn(*args)
            jax.block_until_ready(out)
            best[k] = min(best[k], (time.perf_counter() - t0) / iters)
    return best


def _bench_point(n: int, num_pods: int, d: int) -> dict:
    @drjax.program(partition_size=n)
    def flat(xs):
        return drjax.reduce_mean(xs)

    @drjax.program(partition_size=n)
    def hier(xs):
        return drjax.hierarchical_reduce_mean(xs, num_supergroups=num_pods)

    @drjax.program(partition_size=n)
    def fused(xs):
        return drjax.hierarchical_reduce_mean(
            xs, num_supergroups=num_pods, compress_fn=int8_roundtrip
        )

    @drjax.program(partition_size=n)
    def unfused(xs):
        return drjax.hierarchical_reduce_mean(
            xs, num_supergroups=num_pods, compress_fn=int8_roundtrip,
            use_fused=False,
        )

    @drjax.program(placements={"pods": num_pods, "clients": n // num_pods})
    def nested(xs):
        return drjax.reduce_mean(xs)  # two primitives via the stack

    xs = jax.random.normal(jax.random.PRNGKey(0), (n, d), jnp.float32)
    xs_nested = xs.reshape(num_pods, n // num_pods, d)
    flat_us, hier_us, fused_us, unfused_us = (
        t * 1e6 for t in _time_interleaved(
            [jax.jit(flat), jax.jit(hier), jax.jit(fused), jax.jit(unfused)],
            (xs,),
        )
    )
    (nested_us,) = (
        t * 1e6 for t in _time_interleaved([jax.jit(nested)], (xs_nested,))
    )
    # Modeled DCN traffic for a production-sized delta (paper §6 scenario):
    # param_bytes is per-group contribution crossing the slow leg. The
    # compressed variants ship int8 + one f32 scale per 256 values (×~3.9
    # fewer bytes than f32).
    param_bytes = xs.dtype.itemsize * d
    model = drjax.cross_pod_bytes(param_bytes, n=n, num_supergroups=num_pods)
    model_c = drjax.cross_pod_bytes(
        param_bytes, n=n, num_supergroups=num_pods, compress="int8"
    )
    # Static analyzer read directly off the plan IR (repro.analysis): the
    # per-stage comm-cost pass splits DCN vs ICI at the bench shapes
    # themselves, independent of the napkin model above.
    dcn_static = {
        name: drjax.build_plan(
            jax.make_jaxpr(prog)(xs), n
        ).comm_cost().dcn_bytes
        for name, prog in (("flat", flat), ("hier", hier), ("fused", fused))
    }
    return {
        "n": n,
        "num_pods": num_pods,
        "payload_floats": d,
        "flat_us_per_call": flat_us,
        "hier_us_per_call": hier_us,
        "fused_us_per_call": fused_us,
        "unfused_compressed_us_per_call": unfused_us,
        "nested_stack_us_per_call": nested_us,
        "fused_vs_flat": fused_us / flat_us,
        "modeled_flat_dcn_bytes": model["flat_bytes"],
        "modeled_hier_dcn_bytes": model["hierarchical_bytes"],
        "modeled_fused_dcn_bytes": model_c["hierarchical_bytes"],
        "modeled_dcn_reduction": model["reduction_factor"],
        "modeled_fused_dcn_reduction": model_c["reduction_factor"],
        # static analyzer column: plan.comm_cost() at the bench shapes
        "dcn_bytes": dcn_static,
    }


def run():
    points = [
        _bench_point(64, 4, 1 << 14),
        _bench_point(256, 8, 1 << 12),
    ]
    # One merge rule for all BENCH_hier writers (executor bench, --hier-sweep
    # sharded point): replace only OUR keys of this commit's entry.
    bench_log.merge_entry({"points": points}, top_points=points)
    rows = []
    for pt in points:
        key = f"hier_reduce_n{pt['n']}_P{pt['num_pods']}"
        rows.append({
            "name": f"{key}_flat",
            "us_per_call": f"{pt['flat_us_per_call']:.1f}",
            "derived": (
                f"dcn_bytes={pt['modeled_flat_dcn_bytes']:.0f}; "
                f"static_dcn={pt['dcn_bytes']['flat']:.0f}"
            ),
        })
        rows.append({
            "name": f"{key}_hier",
            "us_per_call": f"{pt['hier_us_per_call']:.1f}",
            "derived": (
                f"dcn_bytes={pt['modeled_hier_dcn_bytes']:.0f}; "
                f"dcn_reduction={pt['modeled_dcn_reduction']:.0f}x"
            ),
        })
        rows.append({
            "name": f"{key}_unfused_int8",
            "us_per_call": f"{pt['unfused_compressed_us_per_call']:.1f}",
            "derived": "compress=int8; use_fused=False",
        })
        rows.append({
            "name": f"{key}_fused_int8",
            "us_per_call": f"{pt['fused_us_per_call']:.1f}",
            "derived": (
                f"fused_vs_flat={pt['fused_vs_flat']:.2f}; "
                f"dcn_bytes={pt['modeled_fused_dcn_bytes']:.0f}; "
                f"dcn_reduction={pt['modeled_fused_dcn_reduction']:.0f}x; "
                f"static_dcn={pt['dcn_bytes']['fused']:.0f}"
            ),
        })
        rows.append({
            "name": f"{key}_nested_stack",
            "us_per_call": f"{pt['nested_stack_us_per_call']:.1f}",
            "derived": "placements=pods/clients",
        })
    return rows


if __name__ == "__main__":
    for row in run():
        print(f"{row['name']},{row['us_per_call']},{row['derived']}")
    print(f"wrote {OUT_PATH}")
