"""Benchmark runner: one module per paper table/figure + roofline extraction.

Prints ``name,us_per_call,derived`` CSV. Figures 4/5/6 spawn subprocesses
with varying fake-device counts; the roofline rows read the dry-run result
cache (run ``scripts/dryrun_sweep.sh`` first for the full 40-cell table).

The ``hier`` bench maintains ``BENCH_hier.json`` as a per-PR *trajectory*:
each run appends an entry keyed by the current git SHA (re-runs at the same
commit replace their entry) instead of overwriting history, so the
flat/fused/unfused wall-clock triple is trackable across PRs.

    PYTHONPATH=src python -m benchmarks.run [--only fig4,fig5,...]
"""

from __future__ import annotations

import argparse
import sys
import traceback

BENCHES = (
    ("table1", "benchmarks.table1_flops"),
    ("micro", "benchmarks.primitives_micro"),
    ("hier", "benchmarks.hier_reduce"),  # also writes BENCH_hier.json
    ("hier_sharded", "benchmarks.hier_sharded"),  # pod-mesh subprocess sweep
    ("executor", "benchmarks.executor"),  # compiled vs interpreted plans
    ("pipeline", "benchmarks.pipeline"),  # 1F1B round; writes BENCH_pipeline.json
    ("serve", "benchmarks.serve"),  # continuous batching; writes BENCH_serve.json
    ("chaos", "benchmarks.chaos"),  # fault-injection soak; writes BENCH_chaos.json
    ("fig4", "benchmarks.fig4_weak_scaling"),
    ("fig5", "benchmarks.fig5_forloop"),
    ("fig6", "benchmarks.fig6_sharding_ablation"),
    ("roofline", "benchmarks.roofline"),
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: "
                         + ",".join(k for k, _ in BENCHES))
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    import importlib

    print("name,us_per_call,derived")
    failures = 0
    for key, modname in BENCHES:
        if only and key not in only:
            continue
        try:
            mod = importlib.import_module(modname)
            for row in mod.run():
                derived = str(row["derived"]).replace(",", ";")
                print(f"{row['name']},{row['us_per_call']},{derived}",
                      flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{key},0,ERROR", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
