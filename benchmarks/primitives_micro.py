"""Microbenchmarks: DrJAX primitive dispatch + trace/compile overhead.

The paper's API promise is that primitives add negligible overhead over the
equivalent raw-jnp program. Measured on CPU (single device, partition purely
logical): per-call wall time of the jitted program and of the raw-jnp
equivalent, plus trace time.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import core as drjax


def _time(fn, *args, iters=50):
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run():
    n, d = 64, 1 << 14
    x = jnp.ones((d,), jnp.float32)

    @drjax.program(partition_size=n)
    def drjax_round(v):
        y = drjax.broadcast(v)
        z = drjax.map_fn(lambda a: jnp.tanh(a) * a + 1.0, y)
        return drjax.reduce_mean(z)

    def raw_round(v):
        y = jnp.broadcast_to(v[None], (n, d))
        z = jnp.tanh(y) * y + 1.0
        return jnp.mean(z, axis=0)

    t_drjax = _time(jax.jit(drjax_round), x)
    t_raw = _time(jax.jit(raw_round), x)

    t0 = time.perf_counter()
    jax.make_jaxpr(drjax_round)(x)
    t_trace = time.perf_counter() - t0

    return [
        {"name": "micro_drjax_round", "us_per_call": round(t_drjax * 1e6, 1),
         "derived": f"n={n},d={d}"},
        {"name": "micro_raw_jnp_round", "us_per_call": round(t_raw * 1e6, 1),
         "derived": f"overhead={(t_drjax / t_raw - 1) * 100:.1f}%"},
        {"name": "micro_trace_time", "us_per_call": round(t_trace * 1e6, 1),
         "derived": "make_jaxpr of broadcast+map+reduce program"},
    ]


if __name__ == "__main__":
    for row in run():
        print(row)
