"""Chaos soak benchmark: survive composed faults, measure what they cost.

Runs :func:`repro.runtime.chaos.run_chaos_soak` (device failures + pod
dropout/regrowth + straggler deadlines + torn/corrupt checkpoints +
concurrent serve bursts with a scheduler fault) and records the soak's
production metrics as a per-PR trajectory in ``BENCH_chaos.json``:

* ``client_retraces`` / ``oracle_extra_traces`` — must stay 0 (the
  zero-retrace elasticity invariant);
* ``straggler.speedup`` and the masked-vs-sync p99/p50 tail ratios — the
  deadline-masking win;
* ``replayed_steps`` / ``fallback_restores`` — the replay cost of recovery
  under broken checkpoints;
* ``oracle_bitwise_equal`` — determinism under recovery.

* ``reshards`` / ``mesh_migrate_ms`` — physical-mode resharding (a real
  degraded (pod, data) mesh rebuilt from surviving devices per elastic
  event) and the cost of migrating server state onto it;
* ``mid_write_kills_injected`` / ``mid_write_kills_survived`` — writer
  killed mid-``arrays.npz``, survived via fallback restore;
* ``serve_p99_contended`` — serve p99 while a training round is in flight
  on the same devices (the co-location contention column).

``--smoke`` is the CI shape: ~20 rounds with 1 device failure, 1 elastic
event, straggler deadlines every round and a checkpoint fault (no BENCH
write). ``--physical`` runs the physical-mesh soak; it needs 8 host
devices and re-execs itself under ``XLA_FLAGS`` when the current process
has fewer. Invoked via ``benchmarks.run`` (key ``chaos``) or directly:

    PYTHONPATH=src python -m benchmarks.chaos [--smoke] [--physical]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.launch import bench_log
from repro.runtime.chaos import ChaosConfig, run_chaos_soak

OUT_PATH = bench_log.bench_path("chaos")
_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: devices the physical soak needs (4 pods x 2 clients)
PHYSICAL_DEVICES = 8


def smoke_config(seed: int = 1) -> ChaosConfig:
    """~20-round CI soak: 1 failure + 1 elastic event + stragglers + 1
    checkpoint fault, serve traffic off (benchmarks.serve covers it).

    Default seed 1: the tail-ratio invariant (masked p99/p50 < sync
    p99/p50) is a statistical property; at 20 rounds a few seeds are too
    noisy to separate the distributions. The schedule is deterministic, so
    a passing seed passes forever."""
    return ChaosConfig(
        rounds=20,
        seed=seed,
        num_device_failures=1,
        num_elastic_events=1,
        num_ckpt_faults=1,
        checkpoint_every=4,
        audit_every=8,
        serve_traffic=False,
    )


def physical_config(seed: int = 1) -> ChaosConfig:
    """The 8-device physical-mesh soak: 4 pods x 2 clients on a real
    (pod, data) mesh, 2 elastic events (>= 1 dropout reshard + >= 1
    regrowth), 1 device failure and 1 mid-write checkpoint kill. Serve is
    off (the logical full soak records the contention column)."""
    return ChaosConfig(
        rounds=20,
        seed=seed,
        num_pods=4,
        clients_per_pod=2,
        num_device_failures=1,
        num_elastic_events=2,
        num_ckpt_faults=1,
        checkpoint_every=4,
        audit_every=8,
        serve_traffic=False,
        physical_mesh=True,
    )


def bench(smoke: bool = False, seed: int | None = None,
          physical: bool = False) -> dict:
    if physical:
        cfg = physical_config() if seed is None else physical_config(seed)
    elif smoke:
        cfg = smoke_config() if seed is None else smoke_config(seed)
    else:
        cfg = ChaosConfig() if seed is None else ChaosConfig(seed=seed)
    report = run_chaos_soak(cfg)  # asserts the production invariants
    point = report.to_json()
    point["mode"] = (
        "physical" if physical else ("smoke" if smoke else "full")
    )
    return point


def _physical_point_subprocess() -> dict:
    """Run the physical soak in a fresh 8-device process, return its point.

    The host device count is locked at JAX's first init, so the aggregator
    (whose process typically has 1 device) gets the physical point from a
    subprocess — the same pattern as benchmarks/hier_sharded.py."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={PHYSICAL_DEVICES}"
    )
    env["PYTHONPATH"] = os.path.join(_REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.chaos",
         "--smoke", "--physical", "--json"],
        capture_output=True, text=True, cwd=_REPO, timeout=900, env=env,
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"physical chaos soak failed:\n{proc.stdout[-2000:]}\n"
            f"{proc.stderr[-2000:]}"
        )
    return json.loads(proc.stdout.strip().splitlines()[-1])


def run():
    t0 = time.time()
    point = bench()
    point["bench_wall_s"] = round(time.time() - t0, 1)
    phys = _physical_point_subprocess()
    bench_log.merge_entry(
        {"chaos": point, "chaos_physical": phys}, name="chaos"
    )
    per_round_us = 1e6 * point["bench_wall_s"] / max(point["rounds"], 1)
    return [
        {
            "name": "chaos_soak",
            "us_per_call": f"{per_round_us:.0f}",
            "derived": (
                f"bitwise={point['oracle_bitwise_equal']}; "
                f"retraces={point['client_retraces']}; "
                f"failures={point['device_failures']}; "
                f"fallbacks={point['fallback_restores']}; "
                f"straggler_speedup={point['straggler']['speedup']}; "
                f"serve_p99_contended={point['serve_p99_contended']}"
            ),
        },
        {
            "name": "chaos_soak_physical",
            "us_per_call": "-",
            "derived": (
                f"bitwise={phys['oracle_bitwise_equal']}; "
                f"reshards={phys['reshards']}; "
                f"mesh_migrate_ms={phys['mesh_migrate_ms']}; "
                f"meshes={phys['meshes_seen']}; "
                f"kills={phys['mid_write_kills_survived']}/"
                f"{phys['mid_write_kills_injected']}"
            ),
        },
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="~20-round CI soak (1 failure, 1 elastic event, "
                         "stragglers, 1 ckpt fault); no BENCH write")
    ap.add_argument("--physical", action="store_true",
                    help="physical-mesh soak (real (pod, data) mesh, live "
                         "resharding); re-execs under XLA_FLAGS if this "
                         f"process has < {PHYSICAL_DEVICES} devices")
    ap.add_argument("--json", action="store_true",
                    help="print the result as one machine-readable JSON line")
    ap.add_argument("--seed", type=int, default=None)
    args = ap.parse_args()
    if args.physical:
        import jax

        if jax.device_count() < PHYSICAL_DEVICES:
            env = dict(os.environ)
            env["XLA_FLAGS"] = (
                "--xla_force_host_platform_device_count="
                f"{PHYSICAL_DEVICES}"
            )
            env["PYTHONPATH"] = os.path.join(_REPO, "src") + (
                os.pathsep + env["PYTHONPATH"]
                if env.get("PYTHONPATH") else ""
            )
            sys.exit(subprocess.run(
                [sys.executable, "-m", "benchmarks.chaos"] + sys.argv[1:],
                cwd=_REPO, env=env,
            ).returncode)
    t0 = time.time()
    point = bench(smoke=args.smoke, seed=args.seed, physical=args.physical)
    point["bench_wall_s"] = round(time.time() - t0, 1)
    if not args.smoke:
        key = "chaos_physical" if args.physical else "chaos"
        bench_log.merge_entry({key: point}, name="chaos")
        if not args.json:
            print(f"wrote {OUT_PATH}")
    if args.json:
        print(json.dumps(point))
    else:
        print(json.dumps(point, indent=2))


if __name__ == "__main__":
    main()
