"""Chaos soak benchmark: survive composed faults, measure what they cost.

Runs :func:`repro.runtime.chaos.run_chaos_soak` (device failures + pod
dropout/regrowth + straggler deadlines + torn/corrupt checkpoints +
concurrent serve bursts with a scheduler fault) and records the soak's
production metrics as a per-PR trajectory in ``BENCH_chaos.json``:

* ``client_retraces`` / ``oracle_extra_traces`` — must stay 0 (the
  zero-retrace elasticity invariant);
* ``straggler.speedup`` and the masked-vs-sync p99/p50 tail ratios — the
  deadline-masking win;
* ``replayed_steps`` / ``fallback_restores`` — the replay cost of recovery
  under broken checkpoints;
* ``oracle_bitwise_equal`` — determinism under recovery.

``--smoke`` is the CI shape: ~20 rounds with 1 device failure, 1 elastic
event, straggler deadlines every round and a checkpoint fault (no BENCH
write). Invoked via ``benchmarks.run`` (key ``chaos``) or directly:

    PYTHONPATH=src python -m benchmarks.chaos [--smoke]
"""

from __future__ import annotations

import argparse
import json
import time

from repro.launch import bench_log
from repro.runtime.chaos import ChaosConfig, run_chaos_soak

OUT_PATH = bench_log.bench_path("chaos")


def smoke_config(seed: int = 1) -> ChaosConfig:
    """~20-round CI soak: 1 failure + 1 elastic event + stragglers + 1
    checkpoint fault, serve traffic off (benchmarks.serve covers it).

    Default seed 1: the tail-ratio invariant (masked p99/p50 < sync
    p99/p50) is a statistical property; at 20 rounds a few seeds are too
    noisy to separate the distributions. The schedule is deterministic, so
    a passing seed passes forever."""
    return ChaosConfig(
        rounds=20,
        seed=seed,
        num_device_failures=1,
        num_elastic_events=1,
        num_ckpt_faults=1,
        checkpoint_every=4,
        audit_every=8,
        serve_traffic=False,
    )


def bench(smoke: bool = False, seed: int | None = None) -> dict:
    if smoke:
        cfg = smoke_config() if seed is None else smoke_config(seed)
    else:
        cfg = ChaosConfig() if seed is None else ChaosConfig(seed=seed)
    report = run_chaos_soak(cfg)  # asserts the production invariants
    point = report.to_json()
    point["mode"] = "smoke" if smoke else "full"
    return point


def run():
    t0 = time.time()
    point = bench()
    point["bench_wall_s"] = round(time.time() - t0, 1)
    bench_log.merge_entry({"chaos": point}, name="chaos")
    per_round_us = 1e6 * point["bench_wall_s"] / max(point["rounds"], 1)
    return [
        {
            "name": "chaos_soak",
            "us_per_call": f"{per_round_us:.0f}",
            "derived": (
                f"bitwise={point['oracle_bitwise_equal']}; "
                f"retraces={point['client_retraces']}; "
                f"failures={point['device_failures']}; "
                f"fallbacks={point['fallback_restores']}; "
                f"straggler_speedup={point['straggler']['speedup']}"
            ),
        },
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="~20-round CI soak (1 failure, 1 elastic event, "
                         "stragglers, 1 ckpt fault); no BENCH write")
    ap.add_argument("--seed", type=int, default=None)
    args = ap.parse_args()
    t0 = time.time()
    point = bench(smoke=args.smoke, seed=args.seed)
    point["bench_wall_s"] = round(time.time() - t0, 1)
    if not args.smoke:
        bench_log.merge_entry({"chaos": point}, name="chaos")
        print(f"wrote {OUT_PATH}")
    print(json.dumps(point, indent=2))


if __name__ == "__main__":
    main()
