"""Paper Fig. 6: DrJAX vs DrJAX-NS (no sharding annotations).

Removing DrJAX's sharding annotations at trace time leaves GSPMD to decide
placement of the partitioned model copies. The paper observes sublinear-but-
significant slowdowns and OOM at scale (1B @ 512 workers; 8B @ ≥2 workers).

Compiled-program evidence here: per-device temp memory of the round. With
annotations the n model copies shard n-ways (flat per-device bytes); without,
at least one stage materializes replicated copies (per-device bytes grow
with n) — the OOM mechanism. We report the bytes and the n at which NS would
exceed a 16 GiB v5e HBM for the paper's 1B model (scaled analytically).
"""

from __future__ import annotations

from . import _util

_BODY = _util.LOCAL_SGD_SNIPPET + """
from repro.algorithms.rounds import LocalSGDConfig, make_local_sgd_round

round_cfg = LocalSGDConfig(
    partition_size=N, num_local_steps=LOCAL_STEPS,
    partition_axes=part_axes, mesh=mesh,
    use_sharding_annotations={annotations},
)
fn = make_local_sgd_round(loss_fn, optim.sgd(0.05),
                          optim.fedavg_momentum(1.0), round_cfg)
sstate = optim.fedavg_momentum(1.0).init(params)
data = {{
    "tokens": jnp.zeros((N, LOCAL_STEPS, B, S), jnp.int32),
    "labels": jnp.zeros((N, LOCAL_STEPS, B, S), jnp.int32),
}}
compiled = jax.jit(fn).lower(params, sstate, data).compile()
mem = compiled.memory_analysis()
print(json.dumps({{
    "n": N, "annotations": {annotations},
    "temp_bytes": mem.temp_size_in_bytes,
    "arg_bytes": mem.argument_size_in_bytes,
}}))
"""


def run():
    rows = {True: [], False: []}
    for ann in (True, False):
        for n in (2, 4, 8):
            rows[ann].append(
                _util.run_point(_BODY, devices=n, partition=n,
                                annotations=ann)
            )
    out = []
    for ann, rr in rows.items():
        tag = "drjax" if ann else "ns"
        base = rr[0]["temp_bytes"] or 1
        for r in rr:
            out.append({
                "name": f"fig6_{tag}_n{r['n']}",
                "us_per_call": 0.0,
                "derived": (
                    f"temp_bytes/device={r['temp_bytes']};"
                    f"rel_n2={r['temp_bytes']/base:.2f}"
                ),
            })
    drj = rows[True][-1]["temp_bytes"] / max(rows[True][0]["temp_bytes"], 1)
    ns = rows[False][-1]["temp_bytes"] / max(rows[False][0]["temp_bytes"], 1)
    out.append({
        "name": "fig6_temp_growth_n8_over_n2",
        "us_per_call": 0.0,
        "derived": f"drjax={drj:.2f} ns={ns:.2f} (>1 grows with n => OOM path)",
    })
    # analytic OOM point for the paper's 1B model under NS replication:
    # one fp32 copy of n client models materialized per device.
    params_1b = 1e9
    hbm = 16 * 2**30
    n_oom = int(hbm // (params_1b * 4))
    out.append({
        "name": "fig6_ns_oom_point_1b_analytic",
        "us_per_call": 0.0,
        "derived": (
            f"replicated f32 client copies exceed 16GiB HBM at n>={n_oom} "
            f"(paper observed 1B OOM at n=512)"
        ),
    })
    return out


if __name__ == "__main__":
    for row in run():
        print(row)
