"""Pipelined (1F1B fill/drain) vs single-stage round: step time + bubble.

Measures the jitted per-call wall time of

* ``single``    — the whole S-stage chain run as ONE stage over all M
  microbatches (the no-pipelining baseline: every microbatch traverses the
  full chain with no stage axis, i.e. what you get without the stage-kind
  placement);
* ``pipelined`` — ``algorithms.make_pipelined_round``'s fill/drain
  schedule: one ``lax.scan`` over M + S - 1 ticks, ``stage_map`` compute +
  ``stage_transfer`` advance per tick;
* ``compiled``  — the SAME pipelined program staged through the plan
  interpreter and lowered by ``plan.compile`` (the §5 path), checked
  bitwise against the eager jit.

and pairs each point with the analytic bubble fraction
``(S-1)/(M+S-1)`` — the idle-slot share of the schedule — plus the static
analyzer's ICI pricing of the per-tick stage transfer read off the plan IR.
On a single CPU host the pipelined variant pays the bubble and the shifted
buffer without any real stage parallelism, so the interesting number is the
overhead ratio, not a speedup; the bubble column is the model-level claim.

``BENCH_pipeline.json`` is a per-PR **trajectory** alongside
``BENCH_hier.json``: each run appends (or replaces, for re-runs at the same
commit) an entry keyed by the current git SHA. Invoked via
``benchmarks.run`` (key ``pipeline``).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import core as drjax
from repro.algorithms import (
    PipelineConfig,
    make_pipelined_round,
    pipeline_bubble_fraction,
)
from repro.launch import bench_log

OUT_PATH = bench_log.bench_path("pipeline")


def _time_interleaved(fns, argss, iters: int = 20, reps: int = 5):
    """Best-of-reps per-call time, reps round-robined across fns so
    transient host load hits every variant equally (same discipline as
    benchmarks.hier_reduce — the ratio is the headline)."""
    for fn, args in zip(fns, argss):
        jax.block_until_ready(fn(*args))  # warmup/compile
    best = [float("inf")] * len(fns)
    for _ in range(reps):
        for k, (fn, args) in enumerate(zip(fns, argss)):
            t0 = time.perf_counter()
            for _ in range(iters):
                out = fn(*args)
            jax.block_until_ready(out)
            best[k] = min(best[k], (time.perf_counter() - t0) / iters)
    return best


def _stage_fns(s: int):
    # Distinct per-stage weights so the chain is order-sensitive (a real
    # MPMD pipeline is heterogeneous); each stage is one dense matmul.
    def make(stage):
        w = jax.random.normal(
            jax.random.PRNGKey(stage), (1,), jnp.float32
        ) * 0.1 + 1.0

        def fn(x):
            return jnp.tanh(x) * w[0]

        return fn

    return tuple(make(i) for i in range(s))


def _bench_point(s: int, m: int, d: int) -> dict:
    fns = _stage_fns(s)
    cfg = PipelineConfig(num_stages=s, num_microbatches=m)
    round_fn = make_pipelined_round(fns, cfg)

    def single(mbs):
        def chain(x):
            for fn in fns:
                x = fn(x)
            return x
        return jax.vmap(chain)(mbs)

    mbs = jax.random.normal(jax.random.PRNGKey(7), (m, d), jnp.float32)
    act0 = jnp.zeros((s, d), jnp.float32)

    plan = drjax.build_plan(
        jax.make_jaxpr(round_fn)(mbs, act0),
        round_fn.drjax_context,
        partitioned_invars=(0, 1),
    )
    compiled = plan.compile()

    # lint: disable=donate-jit  (bench baselines; inputs reused every rep)
    single_us, pipe_us, compiled_us = (
        t * 1e6 for t in _time_interleaved(
            [jax.jit(single), jax.jit(round_fn), compiled],
            [(mbs,), (mbs, act0), (mbs, act0)],
        )
    )

    cost = plan.comm_cost()
    transfer = [c for c in cost.per_stage if c.op == "stage_transfer"]
    return {
        "num_stages": s,
        "num_microbatches": m,
        "payload_floats": d,
        "single_us_per_call": single_us,
        "pipelined_us_per_call": pipe_us,
        "compiled_us_per_call": compiled_us,
        "pipelined_vs_single": pipe_us / single_us,
        "bubble_fraction": pipeline_bubble_fraction(s, m),
        "ticks": m + s - 1,
        "transfer_ici_bytes": sum(c.wire_bytes for c in transfer),
        "trace_count": compiled.trace_count,
    }


def run():
    points = [
        _bench_point(2, 8, 1 << 12),
        _bench_point(4, 16, 1 << 10),
    ]
    bench_log.merge_entry(
        {"points": points}, top_points=points, name="pipeline"
    )
    rows = []
    for pt in points:
        key = (f"pipeline_S{pt['num_stages']}_M{pt['num_microbatches']}"
               f"_d{pt['payload_floats']}")
        rows.append({
            "name": f"{key}_single",
            "us_per_call": f"{pt['single_us_per_call']:.1f}",
            "derived": "no stage axis; vmapped chain",
        })
        rows.append({
            "name": f"{key}_pipelined",
            "us_per_call": f"{pt['pipelined_us_per_call']:.1f}",
            "derived": (
                f"bubble={pt['bubble_fraction']:.3f}; "
                f"ticks={pt['ticks']}; "
                f"vs_single={pt['pipelined_vs_single']:.2f}"
            ),
        })
        rows.append({
            "name": f"{key}_compiled_plan",
            "us_per_call": f"{pt['compiled_us_per_call']:.1f}",
            "derived": (
                f"transfer_ici_bytes={pt['transfer_ici_bytes']:.0f}; "
                f"trace_count={pt['trace_count']}"
            ),
        })
    return rows


if __name__ == "__main__":
    for row in run():
        print(f"{row['name']},{row['us_per_call']},{row['derived']}")
    print(f"wrote {OUT_PATH}")
