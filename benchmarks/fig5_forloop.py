"""Paper Fig. 5: "JIT compilation alone is not enough".

The same local-SGD round written as a Python for-loop over groups (jit'd,
identical input/output shardings) vs the DrJAX version. On hardware, the
paper shows the for-loop round time grows linearly with partition size while
DrJAX stays constant. The compiled-program evidence for that behavior:

 * DrJAX: per-device HLO FLOPs stay ~flat as n and devices grow together
   (the partitioned dimension is sharded);
 * for-loop: per-device FLOPs grow ~linearly in n — XLA does not recover
   cross-iteration parallelism from a data-independent Python loop, so every
   device executes all n group updates.

We also record compile time (the for-loop program's HLO grows with n).
"""

from __future__ import annotations

from . import _util

_BODY = _util.LOCAL_SGD_SNIPPET + """
from repro.optim.optimizers import apply_updates

client_opt = optim.sgd(0.05)

def client_update(params0, client_data):
    opt_state = client_opt.init(params0)
    def one_step(carry, batch):
        p, s = carry
        loss, g = jax.value_and_grad(loss_fn)(p, batch)
        upd, s = client_opt.update(g, s, p)
        return (apply_updates(p, upd), s), loss
    (p1, _), losses = jax.lax.scan(one_step, (params0, opt_state), client_data)
    delta = jax.tree_util.tree_map(
        lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32), p1, params0)
    return delta, jnp.mean(losses)

data = {{
    "tokens": jnp.zeros((N, LOCAL_STEPS, B, S), jnp.int32),
    "labels": jnp.zeros((N, LOCAL_STEPS, B, S), jnp.int32),
}}

MODE = "{mode}"
if MODE == "drjax":
    from repro.algorithms.rounds import LocalSGDConfig, make_local_sgd_round
    round_cfg = LocalSGDConfig(partition_size=N, num_local_steps=LOCAL_STEPS,
                               partition_axes=part_axes, mesh=mesh)
    fn = make_local_sgd_round(loss_fn, client_opt,
                              optim.fedavg_momentum(1.0), round_cfg)
    sstate = optim.fedavg_momentum(1.0).init(params)
    lower_args = (params, sstate, data)
else:
    # naive double for-loop over groups (outer loop has no data dependency)
    def fn(params, sstate, data):
        deltas, losses = [], []
        for i in range(N):
            client = jax.tree_util.tree_map(lambda x: x[i], data)
            d, l = client_update(params, client)
            deltas.append(d)
            losses.append(l)
        mean_delta = jax.tree_util.tree_map(
            lambda *xs: sum(xs) / N, *deltas)
        new_params = jax.tree_util.tree_map(
            lambda p, d: (p.astype(jnp.float32) + d).astype(p.dtype),
            params, mean_delta)
        return new_params, sstate, {{"loss": sum(losses) / N}}
    sstate = optim.fedavg_momentum(1.0).init(params)
    lower_args = (params, sstate, data)

t0 = time.time()
compiled = jax.jit(fn).lower(*lower_args).compile()
compile_s = time.time() - t0
cost = compat.cost_analysis(compiled)
t0 = time.time()
r = jax.jit(fn)(*lower_args)
jax.block_until_ready(r[2]["loss"])
wall_s = time.time() - t0
print(json.dumps({{
    "mode": MODE, "n": N, "devices": DEVICES,
    "flops_per_device": cost.get("flops", 0.0),
    "compile_s": compile_s, "wall_s": wall_s,
}}))
"""


def run():
    rows = {"drjax": [], "forloop": []}
    for mode in ("drjax", "forloop"):
        for n in (2, 4, 8):
            rows[mode].append(
                _util.run_point(_BODY, devices=n, partition=n, mode=mode)
            )
    out = []
    for mode, rr in rows.items():
        base = rr[0]["flops_per_device"] or 1.0
        for r in rr:
            out.append({
                "name": f"fig5_{mode}_n{r['n']}",
                "us_per_call": round(r["wall_s"] * 1e6, 1),
                "derived": (
                    f"flops/device={r['flops_per_device']:.3e};"
                    f"rel_n2={r['flops_per_device']/base:.2f};"
                    f"compile_s={r['compile_s']:.2f}"
                ),
            })
    drj = rows["drjax"][-1]["flops_per_device"] / (
        rows["drjax"][0]["flops_per_device"] or 1.0)
    fl = rows["forloop"][-1]["flops_per_device"] / (
        rows["forloop"][0]["flops_per_device"] or 1.0)
    out.append({
        "name": "fig5_scaling_ratio_n8_over_n2",
        "us_per_call": 0.0,
        "derived": f"drjax={drj:.2f} (flat) forloop={fl:.2f} (~4 = linear)",
    })
    return out


if __name__ == "__main__":
    for row in run():
        print(row)
