"""Sharded hierarchical-reduce point: subprocess wrapper for benchmarks.run.

The measurement itself lives in the dry-run driver
(``repro.launch.dryrun --hier-sweep``): it needs a multi-device (pod, data)
mesh, and the host device count is locked at JAX's first init — so it must
run in a fresh process with ``XLA_FLAGS`` forcing a small fake pool (the
same pattern as the fig4/fig5 weak-scaling benches). The sweep appends the
sharded point to this commit's ``BENCH_hier.json`` trajectory entry.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run(num_devices: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={num_devices}"
    )
    env["PYTHONPATH"] = os.path.join(_REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--hier-sweep"],
        capture_output=True, text=True, cwd=_REPO, timeout=600, env=env,
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"--hier-sweep failed:\n{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}"
        )
    payload = json.loads(proc.stdout.strip().splitlines()[-1])
    pt = payload["hier_sweep"]
    key = f"hier_sharded_P{pt['num_pods']}x{pt['mesh']['data']}dev"
    return [
        {
            "name": f"{key}_flat",
            "us_per_call": f"{pt['flat_us_per_call']:.1f}",
            "derived": f"devices={pt['devices']}",
        },
        {
            "name": f"{key}_hier",
            "us_per_call": f"{pt['hier_us_per_call']:.1f}",
            "derived": f"hier_vs_flat={pt['hier_vs_flat']:.2f}",
        },
        {
            "name": f"{key}_fused_int8",
            "us_per_call": f"{pt['fused_us_per_call']:.1f}",
            "derived": f"fused_vs_flat={pt['fused_vs_flat']:.2f}",
        },
    ]


if __name__ == "__main__":
    for row in run():
        print(f"{row['name']},{row['us_per_call']},{row['derived']}")
