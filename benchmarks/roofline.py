"""Roofline table: aggregates the dry-run results (launch/dryrun.py) into
the per-(arch × shape × mesh) three-term roofline rows for EXPERIMENTS.md.

The per-cell costs in those artifacts are produced by
``repro.launch.hlo_cost`` on top of ``repro.compat.cost_analysis`` (the raw
compiled-cost shape differs across JAX versions); this module only formats
the normalized numbers."""

from __future__ import annotations

import json
import os

RESULTS_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "dryrun_results")


def load_results():
    rows = []
    if not os.path.isdir(RESULTS_DIR):
        return rows
    for name in sorted(os.listdir(RESULTS_DIR)):
        if name.endswith(".json"):
            with open(os.path.join(RESULTS_DIR, name)) as f:
                rows.append(json.load(f))
    return rows


def run():
    out = []
    for r in load_results():
        name = f"roofline_{r['arch']}_{r['cell']}_{r['mesh']}"
        if r.get("algorithm", "sgd") != "sgd":
            name += f"_{r['algorithm']}"
        if r["status"] == "skipped":
            out.append({"name": name, "us_per_call": 0.0,
                        "derived": f"SKIPPED: {r['reason']}"})
            continue
        if r["status"] != "ok":
            out.append({"name": name, "us_per_call": 0.0,
                        "derived": f"ERROR: {r.get('error', '?')[:120]}"})
            continue
        rf = r["roofline"]
        out.append({
            "name": name,
            "us_per_call": round(rf["step_time_lower_bound_s"] * 1e6, 1),
            "derived": (
                f"compute_s={rf['compute_s']:.4g};memory_s={rf['memory_s']:.4g};"
                f"collective_s={rf['collective_s']:.4g};dom={rf['dominant']};"
                f"mfu_overlap={rf.get('mfu_overlap', 0):.3f};"
                f"useful_ratio={rf['useful_flops_ratio']:.3f};"
                f"peakHBM_GiB={r['memory']['peak_hbm_bytes']/2**30:.1f}"
            ),
        })
    if not out:
        out.append({"name": "roofline_missing", "us_per_call": 0.0,
                    "derived": "run scripts/dryrun_sweep.sh first"})
    return out


if __name__ == "__main__":
    for row in run():
        print(row)
