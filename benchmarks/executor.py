"""Compiled plan executor vs interpreted ``run_plan``: dispatch overhead.

Measures the per-round wall clock of the local-SGD round plan executed

* ``interpreted`` — ``run_plan``, the §5 reference executor (one eager
  dispatch per eqn, control flow on the host);
* ``compiled``   — ``plan.compile()``, the whole plan lowered to ONE jitted
  executable (PR-5);
* ``compiled_donated`` — same, with params/server_state donated (the hot
  round-loop form);

plus the multi-round trainer (a LOOP-stage plan: ``lax.scan`` inside the
executable vs the interpreter's per-iteration Python loop).

Two invariants are ASSERTED, not just reported:
 * compiled output is bitwise-equal to ``run_plan`` (CPU correctness bar);
 * N rounds after warmup trigger ZERO retraces (trace-counter check), and
   re-compiling a structurally identical re-built plan is a cache hit.

Results are merged into this commit's ``BENCH_hier.json`` trajectory entry
under ``"executor"`` (shared with ``hier_reduce``'s wall-clock points).
Invoked via ``benchmarks.run`` (key ``executor``).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import core as drjax
from repro import optim
from repro.algorithms.rounds import (
    LocalSGDConfig,
    make_local_sgd_round,
    make_multi_round,
)
from repro.launch import bench_log
from repro.runtime import executor as executor_lib

OUT_PATH = bench_log.bench_path()


def _quadratic_round(n=8, steps=2, dim=16):
    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"] + params["b"]
        return jnp.mean((pred - batch["y"]) ** 2)

    params = {
        "w": jax.random.normal(jax.random.PRNGKey(0), (dim,)),
        "b": jnp.float32(0.0),
    }
    data = {
        "x": jax.random.normal(jax.random.PRNGKey(1), (n, steps, 8, dim)),
        "y": jax.random.normal(jax.random.PRNGKey(2), (n, steps, 8)),
    }
    server = optim.fedavg_momentum(1.0)
    cfg = LocalSGDConfig(partition_size=n, num_local_steps=steps)
    round_fn = make_local_sgd_round(loss_fn, optim.sgd(0.05), server, cfg)
    return round_fn, params, server.init(params), data


def _time_per_call(fn, iters=50, reps=5):
    fn()  # warmup (compile on the compiled path)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn()
        jax.block_until_ready(jax.tree_util.tree_leaves(out))
        best = min(best, (time.perf_counter() - t0) / iters)
    return best


def _assert_bitwise(a_list, b_list, what: str):
    assert len(a_list) == len(b_list)
    for a, b in zip(a_list, b_list):
        if not np.array_equal(np.asarray(a), np.asarray(b)):
            raise AssertionError(f"{what}: compiled != run_plan (bitwise)")


def run():
    round_fn, params, sstate, data = _quadratic_round()
    flat = jax.tree_util.tree_leaves((params, sstate, data))

    # --- single round plan -------------------------------------------------
    plan = drjax.build_plan(
        jax.make_jaxpr(round_fn)(params, sstate, data), 8
    )
    compiled = plan.compile()
    _assert_bitwise(
        list(compiled(*flat)), drjax.run_plan(plan, *flat), "round"
    )

    interp_s = _time_per_call(lambda: drjax.run_plan(plan, *flat))
    comp_s = _time_per_call(lambda: compiled(*flat))

    # Zero retraces across rounds: N more calls must not trace again.
    traces_after_warmup = compiled.trace_count
    for _ in range(20):
        compiled(*flat)
    retraces = compiled.trace_count - traces_after_warmup
    assert retraces == 0, f"compiled round retraced {retraces}x across rounds"
    assert traces_after_warmup == 1, "compiled round traced more than once"

    # Executable cache: a re-built (structurally identical) plan is a HIT.
    plan2 = drjax.build_plan(
        jax.make_jaxpr(round_fn)(params, sstate, data), 8
    )
    compiled2 = plan2.compile()
    compiled2(*flat)
    assert compiled2.trace_count == 1, "re-planned program missed the cache"

    # Donated hot-loop form (fresh buffers per call so donation is real).
    donate_idx = tuple(
        range(len(jax.tree_util.tree_leaves((params, sstate))))
    )
    compiled_d = plan.compile(donate_argnums=donate_idx)

    def donated_round():
        carried = [jnp.array(x) for x in flat[: len(donate_idx)]]
        return compiled_d(*carried, *flat[len(donate_idx):])

    donated_s = _time_per_call(donated_round)

    # --- multi-round trainer (LOOP stage -> lax.scan in the executable) ----
    num_rounds = 8
    trainer = make_multi_round(round_fn, num_rounds)
    all_data = jax.tree_util.tree_map(
        lambda x: jnp.stack([x] * num_rounds), data
    )
    tflat = jax.tree_util.tree_leaves((params, sstate, all_data))
    tplan = drjax.build_plan(
        jax.make_jaxpr(jax.jit(trainer))(params, sstate, all_data), 8
    )
    tcompiled = tplan.compile()
    _assert_bitwise(
        list(tcompiled(*tflat)), drjax.run_plan(tplan, *tflat), "trainer"
    )
    interp_loop_s = _time_per_call(
        lambda: drjax.run_plan(tplan, *tflat), iters=5, reps=3
    )
    comp_loop_s = _time_per_call(lambda: tcompiled(*tflat), iters=5, reps=3)
    assert tcompiled.trace_count == 1

    point = {
        "round_interpreted_us": interp_s * 1e6,
        "round_compiled_us": comp_s * 1e6,
        "round_compiled_donated_us": donated_s * 1e6,
        "round_speedup": interp_s / comp_s,
        "trainer_rounds": num_rounds,
        "trainer_interpreted_us": interp_loop_s * 1e6,
        "trainer_compiled_us": comp_loop_s * 1e6,
        "trainer_speedup": interp_loop_s / comp_loop_s,
        "retraces_after_warmup": retraces,
        "stage_units_fused": tcompiled.num_stage_units,
        "stage_units_interpreted": len(tplan.stages),
    }
    bench_log.merge_entry({"executor": point})

    if comp_s > interp_s:
        raise AssertionError(
            f"compiled per-round dispatch ({comp_s*1e6:.1f}us) slower than "
            f"interpreted run_plan ({interp_s*1e6:.1f}us)"
        )

    return [
        {
            "name": "executor_round_interpreted",
            "us_per_call": f"{interp_s*1e6:.1f}",
            "derived": "run_plan (eager reference)",
        },
        {
            "name": "executor_round_compiled",
            "us_per_call": f"{comp_s*1e6:.1f}",
            "derived": (
                f"speedup={interp_s/comp_s:.1f}x; retraces={retraces}"
            ),
        },
        {
            "name": "executor_round_compiled_donated",
            "us_per_call": f"{donated_s*1e6:.1f}",
            "derived": "donate params+server_state",
        },
        {
            "name": f"executor_trainer{num_rounds}_interpreted",
            "us_per_call": f"{interp_loop_s*1e6:.1f}",
            "derived": "LOOP stage via python loop",
        },
        {
            "name": f"executor_trainer{num_rounds}_compiled",
            "us_per_call": f"{comp_loop_s*1e6:.1f}",
            "derived": (
                f"speedup={interp_loop_s/comp_loop_s:.1f}x; "
                f"lax.scan in-executable"
            ),
        },
    ]


if __name__ == "__main__":
    for row in run():
        print(f"{row['name']},{row['us_per_call']},{row['derived']}")
    print(f"merged executor point into {OUT_PATH}")
