"""Paper Fig. 4: weak scaling of DrJAX local SGD.

The paper's claim: with partition size and devices scaled together (fixed
per-group work), round time stays ~constant. Wall-clock on one CPU core
cannot show this, so we measure the quantity that *determines* it on a real
cluster: per-device HLO FLOPs and per-device peak memory from the compiled
SPMD program, at n = devices ∈ {1, 2, 4, 8} with fixed per-group work.
Flat per-device FLOPs/memory ⇒ constant round time on hardware that provides
the devices (plus the synchronization overhead the paper also notes).
"""

from __future__ import annotations

from . import _util


def run():
    rows = []
    for n in (1, 2, 4, 8):
        res = _util.run_point(
            _util.LOCAL_SGD_SNIPPET + """
round_cfg = LocalSGDConfig(
    partition_size=N, num_local_steps=LOCAL_STEPS,
    partition_axes=part_axes, mesh=mesh,
)
round_fn = make_local_sgd_round(
    loss_fn, optim.sgd(0.05), optim.fedavg_momentum(1.0), round_cfg)
sstate = optim.fedavg_momentum(1.0).init(params)
data = {{
    "tokens": jnp.zeros((N, LOCAL_STEPS, B, S), jnp.int32),
    "labels": jnp.zeros((N, LOCAL_STEPS, B, S), jnp.int32),
}}
t0 = time.time()
lowered = jax.jit(round_fn).lower(params, sstate, data)
compiled = lowered.compile()
compile_s = time.time() - t0
cost = compat.cost_analysis(compiled)
mem = compiled.memory_analysis()
# wall-clock for one round (all devices emulated on one core: total work)
import numpy as _np
args = jax.device_put((params, sstate, data))
out = compiled(*jax.tree_util.tree_leaves((params, sstate, data))) if False else None
t0 = time.time()
r = jax.jit(round_fn)(params, sstate, data)
jax.block_until_ready(r[2]["loss"])
wall_s = time.time() - t0
print(json.dumps({{
    "n": N, "devices": DEVICES,
    "flops_per_device": cost.get("flops", 0.0),
    "temp_bytes_per_device": mem.temp_size_in_bytes,
    "compile_s": compile_s,
    "wall_s_total_work": wall_s,
}}))
""",
            devices=n,
            partition=n,
        )
        rows.append(res)
    base = rows[0]["flops_per_device"] or 1.0
    out = []
    for r in rows:
        out.append({
            "name": f"fig4_weak_scaling_n{r['n']}",
            "us_per_call": round(r["wall_s_total_work"] * 1e6, 1),
            "derived": (
                f"flops/device={r['flops_per_device']:.3e};"
                f"rel_to_n1={r['flops_per_device']/base:.3f};"
                f"temp_bytes/device={r['temp_bytes_per_device']}"
            ),
        })
    # headline: per-device flops stay flat (weak scaling)
    rel = rows[-1]["flops_per_device"] / base
    out.append({
        "name": "fig4_weak_scaling_flatness",
        "us_per_call": 0.0,
        "derived": f"flops_per_device_n8_over_n1={rel:.3f} (1.0 == ideal)",
    })
    return out


if __name__ == "__main__":
    for row in run():
        print(row)
