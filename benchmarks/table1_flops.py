"""Paper Table 1: tokens and FLOPs per local-SGD round.

The paper reports, per model, the max partition size with tokens/round and
forward FLOPs/round under the approximation "a forward pass on a model of
size d uses d FLOPs **per example**". We reproduce those numbers exactly
from our configs and also report the standard 6·N·D accounting (which the
paper's approximation understates by ~2·seq_len/3).
"""

from __future__ import annotations

from repro.models import registry

# (arch, partition size, num workers) — paper Table 1 rows
ROWS = [
    ("lm_350m", 2048, 0.35e9, 3.355e7, 2.293e13),
    ("lm_1b", 512, 1e9, 8.389e6, 1.638e13),
    ("lm_8b", 128, 8e9, 2.097e6, 3.277e13),
]

LOCAL_STEPS, BATCH, SEQ = 4, 8, 512


def run():
    out = []
    for arch, n, d_paper, tokens_paper, flops_paper in ROWS:
        cfg = registry.get_config(arch)
        tokens = LOCAL_STEPS * BATCH * SEQ * n
        examples = LOCAL_STEPS * BATCH * n
        flops_paper_approx = examples * d_paper  # d FLOPs per example
        flops_6nd = 6.0 * cfg.param_count() * tokens  # train accounting
        out.append({
            "name": f"table1_{arch}_n{n}",
            "us_per_call": 0.0,
            "derived": (
                f"tokens/round={tokens:.4g} (paper {tokens_paper:.4g}, "
                f"match={abs(tokens - tokens_paper) / tokens_paper < 0.01}); "
                f"fwd_flops_paper_approx={flops_paper_approx:.4g} "
                f"(paper {flops_paper:.4g}, "
                f"match={abs(flops_paper_approx - flops_paper) / flops_paper < 0.01}); "
                f"train_flops_6ND={flops_6nd:.4g}; "
                f"params={cfg.param_count()/1e9:.2f}B"
            ),
        })
    return out


if __name__ == "__main__":
    for row in run():
        print(row)
