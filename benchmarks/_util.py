"""Shared helpers: run a measurement snippet in a subprocess with a chosen
fake-device count (the device count is locked at first JAX init, so every
(devices, partition) point needs a fresh process)."""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

PREAMBLE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
import json, time
import jax, jax.numpy as jnp
import numpy as np
from repro import compat
from repro import core as drjax
"""


def run_point(body: str, devices: int = 1, timeout: int = 540, **fmt) -> dict:
    script = PREAMBLE.format(devices=devices) + textwrap.dedent(body).format(
        devices=devices, **fmt
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, env=env, timeout=timeout,
    )
    if out.returncode != 0:
        raise RuntimeError(
            f"benchmark point failed:\n{out.stderr[-2000:]}"
        )
    return json.loads(out.stdout.strip().splitlines()[-1])


# A small but real transformer round used by fig4/fig5/fig6 (same workload
# family as the paper's local SGD: L layers, d_model, per-group batches).
LOCAL_SGD_SNIPPET = """
import functools
from repro.models import registry
from repro import optim
from repro.algorithms.rounds import LocalSGDConfig, make_local_sgd_round
from repro.launch import mesh as mesh_lib

cfg = registry.get_config("lm_350m").reduced(
    num_layers=2, d_model=128, num_heads=4, head_dim=32, d_ff=512,
    vocab_size=1024,
)
loss_fn = functools.partial(registry.loss_fn, cfg)
params = registry.init_params(jax.random.PRNGKey(0), cfg)
N = {partition}
DEVICES = {devices}
LOCAL_STEPS, B, S = 4, 2, 64

mesh = None
part_axes = None
if DEVICES > 1:
    mesh = mesh_lib.make_mesh((DEVICES,), ("data",))
    part_axes = "data"
"""
