"""Continuous batching vs static waves under a heavy-tailed arrival trace.

Drives both serve schedulers (:mod:`repro.launch.serve`) over the SAME
Poisson-arrival / lognormal-length request trace at a reduced config and
reports sustained throughput and latency percentiles:

* ``tokens_per_s`` / ``requests_per_s`` — sustained rates over the trace
  (scheduler-clock duration: the clock advances by measured step wall time
  and jumps over idle gaps);
* ``ttft_p50`` / ``ttft_p99`` — time-to-first-token (first-token clock
  minus arrival; for static waves this includes waiting for earlier waves
  to drain, which is exactly the effect continuous batching removes);
* ``itl_p50`` / ``itl_p99`` — inter-token latency, pooled across requests.

Both schedulers are warmed on a bucket-covering trace first (a prompt of
``2 * chunk - 1`` tokens touches every power-of-two chunk bucket), then the
measured run asserts the steady-state invariant: ZERO new traces under
arbitrary traffic (``prefill_traces`` / ``decode_traces`` flat).

``BENCH_serve.json`` is a per-PR trajectory via the generalized
``bench_log`` (one entry per git SHA). Invoked via ``benchmarks.run``
(key ``serve``) or directly:

    PYTHONPATH=src python -m benchmarks.serve [--smoke]
"""

from __future__ import annotations

import argparse
import time
from typing import List, Optional

import jax
import numpy as np

from repro.launch import bench_log
from repro.launch.serve import (
    ContinuousBatchingScheduler,
    Request,
    StaticWaveScheduler,
)
from repro.models import registry

OUT_PATH = bench_log.bench_path("serve")
ARCH = "stablelm_3b"


def heavy_tailed_trace(rng, n: int, rate: float = 1.0, *,
                       mean_prompt: float = 10.0, mean_out: float = 8.0,
                       sigma: float = 0.8, max_prompt: int = 48,
                       max_out: int = 24):
    """Lognormal prompt/output lengths + Poisson arrivals — the traffic
    shape that collapses static waves (one straggler pins a whole wave)."""
    prompts = np.clip(
        rng.lognormal(np.log(mean_prompt), sigma, n), 1, max_prompt
    ).astype(int)
    outs = np.clip(
        rng.lognormal(np.log(mean_out), sigma, n), 1, max_out
    ).astype(int)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n))
    return [
        {"prompt_len": int(p), "max_new": int(o), "arrival": float(a)}
        for p, o, a in zip(prompts, outs, arrivals)
    ]


def rescale_arrivals(trace, rate: float):
    """Rescale a unit-rate Poisson trace to ``rate`` req/s (gaps are
    exponential, so dividing timestamps by the rate is exact)."""
    return [dict(t, arrival=t["arrival"] / rate) for t in trace]


def _requests(trace, rng, vocab: int) -> List[Request]:
    return [
        Request(
            rid=i,
            prompt=rng.integers(0, vocab, (t["prompt_len"],)).astype(np.int32),
            max_new=t["max_new"],
            arrival=t["arrival"],
        )
        for i, t in enumerate(trace)
    ]


def _metrics(reqs: List[Request]) -> dict:
    tokens = sum(len(r.generated) for r in reqs)
    duration = max(max(r.token_times) for r in reqs if r.token_times)
    ttft = np.array([r.t_first - r.arrival for r in reqs])
    gaps = np.concatenate(
        [np.diff(r.token_times) for r in reqs if len(r.token_times) > 1]
        or [np.zeros(1)]
    )
    return {
        "tokens": tokens,
        "duration_s": round(float(duration), 4),
        "tokens_per_s": round(tokens / duration, 1),
        "requests_per_s": round(len(reqs) / duration, 2),
        "ttft_p50_s": round(float(np.percentile(ttft, 50)), 4),
        "ttft_p99_s": round(float(np.percentile(ttft, 99)), 4),
        "itl_p50_s": round(float(np.percentile(gaps, 50)), 5),
        "itl_p99_s": round(float(np.percentile(gaps, 99)), 5),
    }


def bench(n: int = 24, slots: int = 4, chunk: int = 8, seed: int = 0,
          rate: Optional[float] = None, smoke: bool = False) -> dict:
    cfg = registry.get_config(ARCH).reduced()
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    max_len = 48 + 24
    cont = ContinuousBatchingScheduler(cfg, params, slots, max_len, chunk)
    stat = StaticWaveScheduler(cfg, params, slots, max_len, chunk)

    # --- warmup: touch every chunk bucket + the decode-only step ---
    def warm_reqs(rng):
        return [
            Request(rid=i, prompt=rng.integers(0, cfg.vocab_size,
                                               (2 * chunk - 1,))
                    .astype(np.int32), max_new=4)
            for i in range(2)
        ]

    rng = np.random.default_rng(seed + 1)
    cont.run(warm_reqs(rng))
    stat.run(warm_reqs(rng))
    warm_traces = (cont.prefill_traces, cont.decode_traces,
                   stat.prefill_traces, stat.decode_traces)

    trace = heavy_tailed_trace(np.random.default_rng(seed), n)
    if rate is None:
        # calibrate offered load to this host: measure steady-state token
        # capacity with every slot busy, then overload 3x so the duration
        # is capacity-bound — that's where slot utilization (what the two
        # schedulers actually differ in) shows up as sustained tokens/s
        rng = np.random.default_rng(seed + 2)
        calib = [
            Request(rid=i, prompt=rng.integers(0, cfg.vocab_size,
                                               (2 * chunk - 1,))
                    .astype(np.int32), max_new=16)
            for i in range(slots)
        ]
        cont.run(calib)
        toks = sum(len(r.generated) for r in calib)
        dur = max(max(r.token_times) for r in calib)
        mean_tokens = float(np.mean([t["max_new"] for t in trace]))
        rate = 3.0 * (toks / dur) / mean_tokens
    trace = rescale_arrivals(trace, rate)
    rng = np.random.default_rng(seed + 3)
    reqs_c = _requests(trace, rng, cfg.vocab_size)
    rng = np.random.default_rng(seed + 3)
    reqs_s = _requests(trace, rng, cfg.vocab_size)

    out_c = cont.run(reqs_c)
    out_s = stat.run(reqs_s)

    # steady-state invariant: flat trace counts under arbitrary traffic
    now_traces = (cont.prefill_traces, cont.decode_traces,
                  stat.prefill_traces, stat.decode_traces)
    assert now_traces == warm_traces, (
        f"serve steps retraced after bucket warmup: {warm_traces} -> "
        f"{now_traces}"
    )
    # scheduling must not change results: token-identical outputs
    assert all(out_c[i] == out_s[i] for i in out_c), (
        "continuous and static schedulers diverged on the same trace"
    )

    point = {
        "arch": ARCH,
        "requests": n,
        "slots": slots,
        "chunk": chunk,
        "rate_req_per_s": round(float(rate), 3),
        "pool_mb": round(
            registry.slot_pool_bytes(cfg, slots, max_len) / 2**20, 3
        ),
        "prefill_traces": cont.prefill_traces,
        "decode_traces": cont.decode_traces,
        "continuous": _metrics(reqs_c),
        "static": _metrics(reqs_s),
    }
    point["tokens_per_s_ratio"] = round(
        point["continuous"]["tokens_per_s"] / point["static"]["tokens_per_s"],
        3,
    )
    point["ttft_p99_ratio"] = round(
        point["static"]["ttft_p99_s"]
        / max(point["continuous"]["ttft_p99_s"], 1e-9),
        3,
    )
    if not smoke:
        assert point["tokens_per_s_ratio"] > 1.0, (
            "continuous batching did not beat static waves on sustained "
            f"tokens/s: {point}"
        )
        assert point["ttft_p99_ratio"] > 1.0, (
            "continuous batching did not beat static waves on p99 TTFT: "
            f"{point}"
        )
    return point


def run():
    point = bench()
    bench_log.merge_entry({"serve": point}, name="serve")
    us_c = 1e6 / point["continuous"]["tokens_per_s"]
    us_s = 1e6 / point["static"]["tokens_per_s"]
    return [
        {
            "name": "serve_continuous",
            "us_per_call": f"{us_c:.1f}",
            "derived": (
                f"ttft_p99={point['continuous']['ttft_p99_s']}s; "
                f"traces p/d={point['prefill_traces']}/"
                f"{point['decode_traces']} flat"
            ),
        },
        {
            "name": "serve_static_wave",
            "us_per_call": f"{us_s:.1f}",
            "derived": (
                f"ttft_p99={point['static']['ttft_p99_s']}s; "
                f"cont/static tokens/s={point['tokens_per_s_ratio']}"
            ),
        },
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run; skips the perf-ordering assertions "
                         "(still asserts flat traces + token identity)")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--rate", type=float, default=None)
    args = ap.parse_args()
    n = args.requests or (8 if args.smoke else 24)
    t0 = time.time()
    point = bench(n=n, rate=args.rate, smoke=args.smoke)
    point["bench_wall_s"] = round(time.time() - t0, 1)
    if not args.smoke:
        bench_log.merge_entry({"serve": point}, name="serve")
        print(f"wrote {OUT_PATH}")
    import json

    print(json.dumps(point, indent=2))


if __name__ == "__main__":
    main()
