"""Recompute the analytic roofline fields of cached dry-run results
(the compiled HLO evidence is untouched; only the model-derived terms are
refreshed when the analytic model changes)."""
import json, glob, os, sys
sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))
from repro.launch import analytic
from repro.models import registry

for f in sorted(glob.glob(os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "benchmarks/dryrun_results/*.json"))):
    r = json.load(open(f))
    if r["status"] != "ok":
        continue
    cfg = registry.get_config(r["arch"])
    shape = registry.SHAPE_CELLS[r["cell"]]
    mesh = (analytic.MeshModel.multi() if r["mesh"] == "multi"
            else analytic.MeshModel.single())
    ana = analytic.analytic_roofline(
        cfg, shape["kind"], shape["global_batch"], shape["seq_len"], mesh)
    r["roofline"] = {k: (round(v, 6) if isinstance(v, float) else v)
                     for k, v in ana.items() if k != "collective_breakdown"}
    r["collective_breakdown"] = {
        k: round(v, 1) for k, v in ana["collective_breakdown"].items()}
    json.dump(r, open(f, "w"), indent=1)
print("refreshed")
