#!/usr/bin/env python
"""Unified repo lint CLI over the ``repro.analysis.lints`` rule registry.

Runs every registered rule (or a named subset) against the repo tree and
reports violations. This is the single home for repo-convention checks —
the compat-surface grep and the donation lint that used to be inline in
``scripts/run_tests.sh`` both live here now.

Usage:
    python scripts/lint.py                 # all rules, human output
    python scripts/lint.py --json          # machine output (CI)
    python scripts/lint.py donate-jit      # one rule
    python scripts/lint.py --list          # show the registry

Suppression: ``# lint: disable=<rule>`` on the flagged line or the line
above (``donate-jit`` also honors its richer ``# no-donate: <reason>``).

Exit status: 0 clean, 1 violations, 2 usage error (unknown rule).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(
    0,
    os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                 "src"),
)

from repro.analysis import lints  # noqa: E402  (after sys.path setup)


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument(
        "rules", nargs="*",
        help="rule names to run (default: every registered rule)",
    )
    parser.add_argument(
        "--json", action="store_true", dest="as_json",
        help="emit a JSON report on stdout",
    )
    parser.add_argument(
        "--root", default=None,
        help="repo root to lint (default: this checkout)",
    )
    parser.add_argument(
        "--list", action="store_true", dest="list_rules",
        help="list registered rules and exit",
    )
    args = parser.parse_args(argv)

    if args.list_rules:
        for name in sorted(lints.RULES):
            print(f"{name}: {lints.RULES[name].description}")
        return 0

    try:
        violations = lints.run_lints(
            root=args.root, rules=args.rules or None,
        )
    except KeyError as e:
        print(f"lint: {e.args[0]}", file=sys.stderr)
        return 2

    ran = sorted(args.rules) if args.rules else sorted(lints.RULES)
    if args.as_json:
        print(json.dumps({
            "ok": not violations,
            "rules": ran,
            "violations": [v.to_dict() for v in violations],
        }, indent=2))
    elif violations:
        print("lint failed:", file=sys.stderr)
        for v in violations:
            print(f"  [{v.rule}] {v.format()}", file=sys.stderr)
    else:
        print(f"lint: OK ({len(ran)} rules)")
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
