#!/usr/bin/env python
"""Donation lint: every ``jax.jit`` in the hot layers must donate or opt out.

The donation rule (ROADMAP "Compiled plan executor"): a jitted hot loop
donates its carried state — params, server/optimizer state, KV caches —
so the executable updates it in place instead of copying per round. This
check walks ``src/repro/algorithms`` and ``src/repro/launch`` with ``ast``
and fails on any ``jax.jit(...)`` call that neither passes
``donate_argnums=``/``donate_argnames=`` nor carries an explicit
``# no-donate: <reason>`` comment on the call line (or the line above) —
so a new jit call site cannot silently omit donation for carried state.

Usage: python scripts/check_donation.py  (run by scripts/run_tests.sh)
"""

from __future__ import annotations

import ast
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SCAN_DIRS = (
    os.path.join(REPO, "src", "repro", "algorithms"),
    os.path.join(REPO, "src", "repro", "launch"),
)
DONATE_KEYWORDS = {"donate_argnums", "donate_argnames"}
MARKER = "# no-donate:"


def _is_jax_jit(call: ast.Call) -> bool:
    f = call.func
    return (
        isinstance(f, ast.Attribute)
        and f.attr == "jit"
        and isinstance(f.value, ast.Name)
        and f.value.id == "jax"
    )


def check_file(path: str) -> list:
    with open(path) as fh:
        src = fh.read()
    lines = src.splitlines()
    problems = []
    for node in ast.walk(ast.parse(src, filename=path)):
        if not (isinstance(node, ast.Call) and _is_jax_jit(node)):
            continue
        if any(kw.arg in DONATE_KEYWORDS for kw in node.keywords):
            continue
        # opt-out marker on the call line or the line above it
        lo = max(node.lineno - 2, 0)
        hi = min(node.end_lineno, len(lines))
        window = lines[lo:hi]
        if any(MARKER in ln for ln in window):
            continue
        rel = os.path.relpath(path, REPO)
        problems.append(
            f"{rel}:{node.lineno}: jax.jit without donate_argnums — donate "
            f"the carried state, or mark the call with "
            f"'{MARKER} <reason>' if no arg is round-to-round state"
        )
    return problems


def main() -> int:
    problems = []
    for root_dir in SCAN_DIRS:
        for dirpath, _dirnames, filenames in os.walk(root_dir):
            for name in sorted(filenames):
                if name.endswith(".py"):
                    problems.extend(check_file(os.path.join(dirpath, name)))
    if problems:
        print("donation lint failed:", file=sys.stderr)
        for p in problems:
            print("  " + p, file=sys.stderr)
        return 1
    print("donation lint: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
