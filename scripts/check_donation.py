#!/usr/bin/env python
"""Donation lint: every ``jax.jit`` in the hot layers must donate or opt out.

Thin shim over the ``donate-jit`` rule of the unified lint registry
(``repro.analysis.lints``; CLI: ``scripts/lint.py``) — kept so existing
invocations and docs pointing here keep working. Output format and exit
semantics are unchanged from the original standalone checker.

Usage: python scripts/check_donation.py  (CI runs scripts/lint.py instead)
"""

from __future__ import annotations

import os
import sys

sys.path.insert(
    0,
    os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                 "src"),
)

from repro.analysis import lints  # noqa: E402  (after sys.path setup)


def main() -> int:
    problems = lints.run_lints(rules=["donate-jit"])
    if problems:
        print("donation lint failed:", file=sys.stderr)
        for v in problems:
            print("  " + v.format(), file=sys.stderr)
        return 1
    print("donation lint: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
