"""§Perf hillclimb report: per-iteration roofline terms for the three cells.

Each iteration adjusts the analytic collective/compute terms per a concrete,
code-level change (flash causal skip, int8 TP collectives, int8 gradient
collectives, DiLoCo sync amortization), with the compiled-HLO measurements
from the dry-run as structural evidence. Prints the table used in
EXPERIMENTS.md §Perf.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.launch import analytic
from repro.models import registry

PEAK, LINK = analytic.PEAK_FLOPS, analytic.LINK_BW


def row(tag, cfg, kind, batch, seq, mesh, *, causal_factor=None,
        tp_fwd=1.0, tp_bwd=1.0, grad=1.0, remat=None):
    """Compute terms with activation-collective factors applied to the
    forward (tp_fwd) / backward (tp_bwd) halves and gradient collectives."""
    base = analytic.analytic_roofline(
        cfg, kind, batch, seq, mesh, causal_factor=causal_factor, remat=remat)
    br = dict(base["collective_breakdown"])
    adj = 0.0
    for k, v in br.items():
        if k == "total":
            continue
        if k in ("tp_allreduce", "moe_combine_allreduce"):
            if kind == "train":
                adj += v * (0.5 * tp_fwd + 0.5 * tp_bwd)
            else:
                adj += v * tp_fwd
        elif k in ("grad_reducescatter", "grad_allreduce"):
            adj += v * grad
        else:  # fsdp_allgather etc.
            adj += v
    coll_s = adj / LINK
    comp_s = base["compute_s"]
    mem_s = base["memory_s"]
    bound = max(comp_s, mem_s, coll_s)
    mfu = base["model_flops"] / (mesh.chips * PEAK * bound)
    print(f"{tag:44s} comp={comp_s:7.3f} mem={mem_s:6.3f} "
          f"coll={coll_s:7.3f} bound={bound:7.3f} MFU={mfu:.3f}")
    return bound, mfu


def main():
    single = analytic.MeshModel.single()

    print("=" * 100)
    print("CELL A: qwen3_moe x train_4k x single-pod "
          "(worst MFU / most collective-bound)")
    cfg = registry.get_config("qwen3_moe")
    row("A0 paper-faithful, full-block attention", cfg, "train", 256, 4096,
        single, causal_factor=1.0)
    row("A1 + flash causal block-skip (default)", cfg, "train", 256, 4096,
        single)
    row("A2 + int8 gradient RS (error-feedback)", cfg, "train", 256, 4096,
        single, grad=0.25)
    row("A3 + int8 fwd TP/combine collectives", cfg, "train", 256, 4096,
        single, grad=0.25, tp_fwd=0.26)
    row("A4 + int8 bwd activation collectives*", cfg, "train", 256, 4096,
        single, grad=0.25, tp_fwd=0.26, tp_bwd=0.26)

    print("=" * 100)
    print("CELL B: qwen2_72b x train_4k x single-pod (flagship dense train)")
    cfg = registry.get_config("qwen2_72b")
    row("B0 paper-faithful, full-block attention", cfg, "train", 256, 4096,
        single, causal_factor=1.0)
    row("B1 + flash causal block-skip (default)", cfg, "train", 256, 4096,
        single)
    row("B2 + int8 fwd TP collectives", cfg, "train", 256, 4096, single,
        tp_fwd=0.26)
    row("B3 + int8 grad RS", cfg, "train", 256, 4096, single, tp_fwd=0.26,
        grad=0.25)
    row("B4 remat full->none (REFUTED: +57GiB/dev)", cfg, "train", 256, 4096,
        single, tp_fwd=0.26, grad=0.25, remat="none")
    row("B5 + int8 bwd activation collectives*", cfg, "train", 256, 4096,
        single, tp_fwd=0.26, tp_bwd=0.26, grad=0.25)

    print("=" * 100)
    print("CELL C: lm_8b x train_4k local-SGD x single-pod (paper technique)")
    cfg = registry.get_config("lm_8b")
    row("C0 paper-faithful local SGD (H=1 sync)", cfg, "train", 256, 4096,
        single, causal_factor=1.0)
    row("C1 + flash causal block-skip (default)", cfg, "train", 256, 4096,
        single)
    row("C2 + int8 client-delta reduction", cfg, "train", 256, 4096, single,
        grad=0.25)
    row("C3 + DiLoCo H=8 (sync amortized 8x)", cfg, "train", 256, 4096,
        single, grad=0.25 / 8)
    row("C4 + int8 fwd TP collectives", cfg, "train", 256, 4096, single,
        grad=0.25 / 8, tp_fwd=0.26)
    row("C5 + int8 bwd activation collectives*", cfg, "train", 256, 4096,
        single, grad=0.25 / 8, tp_fwd=0.26, tp_bwd=0.26)
    print("\n* bwd activation quantization requires error feedback on the")
    print("  score gradients; flagged as research-grade (see EXPERIMENTS.md).")


if __name__ == "__main__":
    main()
