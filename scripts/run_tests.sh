#!/usr/bin/env bash
# Tier-1 test suite in one command:  scripts/run_tests.sh [pytest args]
#
#   scripts/run_tests.sh                 # full suite
#   scripts/run_tests.sh -m 'not slow'   # fast run (skips multi-device tests)
#
# REPRO_HOST_DEVICES (4 or 8, default 8) sets the fake host-device count for
# the multi-device worker that tests/conftest.py spawns (it exports
# XLA_FLAGS=--xla_force_host_platform_device_count=$REPRO_HOST_DEVICES into
# that worker's environment). XLA_FLAGS is deliberately NOT exported here:
# the main pytest process must keep the default single host device — only
# the session worker forces the count.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export REPRO_HOST_DEVICES="${REPRO_HOST_DEVICES:-8}"

# Compat convention check (ROADMAP.md): no direct version-sensitive JAX
# surfaces outside repro/compat. Must be empty or the run fails.
violations="$(grep -rn --include='*.py' 'AxisType\|cost_analysis()' src/ | grep -v compat || true)"
if [ -n "$violations" ]; then
  echo "compat violation: version-sensitive JAX API used outside repro/compat:" >&2
  echo "$violations" >&2
  exit 1
fi

# Examples smoke-run: the quickstart exercises the full authoring surface
# (flat + nested placements, plan IR, Beam emitter) end to end.
python examples/quickstart.py > /dev/null

exec python -m pytest -q "$@"
