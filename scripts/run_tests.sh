#!/usr/bin/env bash
# Tier-1 test suite in one command:  scripts/run_tests.sh [pytest args]
#
#   scripts/run_tests.sh                 # full suite
#   scripts/run_tests.sh -m 'not slow'   # fast run (skips multi-device tests)
#
# REPRO_HOST_DEVICES (4 or 8, default 8) sets the fake host-device count for
# the multi-device worker that tests/conftest.py spawns (it exports
# XLA_FLAGS=--xla_force_host_platform_device_count=$REPRO_HOST_DEVICES into
# that worker's environment). XLA_FLAGS is deliberately NOT exported here:
# the main pytest process must keep the default single host device — only
# the session worker forces the count.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export REPRO_HOST_DEVICES="${REPRO_HOST_DEVICES:-8}"

# Repo lints (ROADMAP "Static analysis & lints"): the unified rule registry
# in repro.analysis.lints — compat surface, donation discipline, version
# branches, jit-of-plan-stages. Replaces the former inline compat grep and
# the scripts/check_donation.py invocation (both rules live in the
# registry); any violation fails tier-1.
python scripts/lint.py --json

# Examples smoke-run: the quickstart exercises the full authoring surface
# (flat + nested placements, plan IR, Beam emitter, fused compressed
# hierarchical reduce, compiled plan executor) end to end.
python examples/quickstart.py > /dev/null

# Compiled-vs-interpreted smoke check: plan.compile() must be BITWISE equal
# to the run_plan oracle for a loop-carrying round program (full coverage in
# tests/test_executor.py).
python - <<'PY'
import jax, jax.numpy as jnp, numpy as np
from repro import core as drjax

@drjax.program(partition_size=3)
def two_rounds(m, ys):
    def body(m, _):
        g = drjax.reduce_mean(
            drjax.map_fn(lambda a, b: a - b, (drjax.broadcast(m), ys)))
        return m - 0.5 * g, g
    m, gs = jax.lax.scan(body, m, None, length=2)
    return m, gs

args = (jnp.float32(0.3), jnp.array([1.0, 2.0, 3.0]))
plan = drjax.build_plan(jax.make_jaxpr(two_rounds)(*args), 3)
compiled = plan.compile()
ref = drjax.run_plan(plan, *args)
out = compiled(*args)
assert all(np.array_equal(np.asarray(a), np.asarray(b))
           for a, b in zip(out, ref)), \
    "compiled plan executor diverged from run_plan (bitwise)"
compiled(*args)
assert compiled.trace_count == 1, "compiled plan retraced on a repeat call"
print("compiled-vs-interpreted smoke check: OK")
PY

# Pipelined-round smoke check: the 1F1B fill/drain schedule (stage-kind
# placement, stage_map + stage_transfer under one scan) must build a plan
# whose compiled executor is BITWISE equal to run_plan with zero retraces
# (full coverage in tests/test_pipeline.py).
python - <<'PY'
import jax, jax.numpy as jnp, numpy as np
from repro import core as drjax
from repro.algorithms import PipelineConfig, make_pipelined_round

fns = (lambda x: x * 2.0, lambda x: x + 1.0)
round_fn = make_pipelined_round(
    fns, PipelineConfig(num_stages=2, num_microbatches=4))
mbs = jnp.arange(4 * 8, dtype=jnp.float32).reshape(4, 8)
act0 = jnp.zeros((2, 8), jnp.float32)
plan = drjax.build_plan(
    jax.make_jaxpr(round_fn)(mbs, act0), round_fn.drjax_context,
    partitioned_invars=(0, 1))
compiled = plan.compile()
ref = drjax.run_plan(plan, mbs, act0)
out = compiled(mbs, act0)
assert all(np.array_equal(np.asarray(a), np.asarray(b))
           for a, b in zip(out, ref)), \
    "compiled pipelined round diverged from run_plan (bitwise)"
compiled(mbs, act0)
assert compiled.trace_count == 1, "pipelined round retraced on repeat call"
plan.analyze().raise_if_errors()
print("pipelined-round smoke check: OK")
PY

# Fused reduce+compress smoke check: the interpret-mode Pallas kernel must be
# BITWISE equal to its jnp oracle (fast; full coverage in test_fused_reduce).
python - <<'PY'
import jax, jax.numpy as jnp
from repro.kernels import reduce_compress as rc, ref

x = jax.random.normal(jax.random.PRNGKey(0), (8, 4, 256), jnp.float32)
q, s = rc.reduce_compress(x, interpret=True)
qr, sr = ref.reduce_compress_ref(x)
assert bool(jnp.all(q == qr)) and bool(jnp.all(s == sr)), \
    "fused reduce_compress kernel diverged from its jnp oracle"
back = rc.dequant_accumulate(q[None], s[None], interpret=True)
br = ref.dequant_accumulate_ref(q[None], s[None])
assert bool(jnp.all(back == br)), \
    "dequant_accumulate kernel diverged from its jnp oracle"
print("fused-vs-oracle smoke check: OK")
PY

# Serve-runtime smoke: continuous batching vs static waves on a reduced
# config — asserts flat trace counts after bucket warmup and token-identical
# outputs across schedulers (perf-ordering assertions are skipped in smoke
# mode; the full comparison runs via benchmarks.run / benchmarks.serve).
python -m benchmarks.serve --smoke > /dev/null
echo "serve continuous-batching smoke check: OK"

# Chaos smoke soak: ~20 rounds with 1 injected device failure, 1 elastic
# event, straggler deadlines and a checkpoint fault — asserts the production
# invariants (bitwise oracle equality, zero client-leg retraces, masked tail
# < sync tail, fallback past the broken checkpoint). The full composed soak
# (2 failures, 4 elastic events, serve traffic) runs in tests/test_chaos.py
# (slow) and benchmarks.chaos.
python -m benchmarks.chaos --smoke > /dev/null
echo "chaos smoke soak: OK"

# Physical chaos smoke: the same soak on a REAL 8-device (pod, data) mesh —
# pod dropout rebuilds a degraded mesh from surviving devices, server state
# migrates onto it, and a mid-arrays.npz writer kill must be survived via
# fallback restore. --physical re-execs in a subprocess under
# XLA_FLAGS=--xla_force_host_platform_device_count=8, so this process keeps
# its single default device (same isolation rule as the conftest worker).
python -m benchmarks.chaos --smoke --physical --json > /dev/null
echo "physical chaos smoke soak: OK"

exec python -m pytest -q "$@"
