"""Quickstart: the paper's authoring surface in 60 lines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro import core as drjax

# --- Snippet 2: broadcast, map, reduce ------------------------------------


@drjax.program(partition_size=3)
def broadcast_double_and_sum(x):
    y = drjax.broadcast(x)
    z = drjax.map_fn(lambda a: 2 * a, y)
    return drjax.reduce_sum(z)


print("snippet 2:", broadcast_double_and_sum(jnp.float32(1.0)), "(expect 6)")


# --- Snippets 3-6: parallel MAML + MapReduce AD ----------------------------


def loss(x, y):
    return (x - y) ** 2


def maml_loss(model, lr, task):
    g = jax.grad(loss)(model, task)
    return loss(model - lr * g, task)


@drjax.program(partition_size=3)
def parallel_maml_loss(model, lr, tasks):
    model_b = drjax.broadcast(model)
    lr_b = drjax.broadcast(lr)
    losses = drjax.map_fn(maml_loss, (model_b, lr_b, tasks))
    return drjax.reduce_mean(losses)


args = (jnp.float32(0.0), jnp.float32(0.1), jnp.array([1.0, 2.0, 3.0]))
print("maml loss:", parallel_maml_loss(*args))
print("maml grad:", jax.grad(parallel_maml_loss)(*args),
      "(a DrJAX program too — MapReduce AD)")

# the jaxpr preserves the primitives (paper Snippet 5)
jxp = jax.make_jaxpr(parallel_maml_loss)(*args)
print("\njaxpr:\n", jxp)

# --- §5: interpret out to other platforms ----------------------------------

plan = drjax.build_plan(jxp, 3)
print("\nfederated plan:\n" + plan.to_text())
print("\nbeam pipeline:\n" + plan.to_beam())

outs = drjax.run_plan(plan, *args)
print("\nplan executor result:", outs[0], "== direct:", parallel_maml_loss(*args))
