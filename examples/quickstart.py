"""Quickstart: the paper's authoring surface in 60 lines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro import core as drjax

# --- Snippet 2: broadcast, map, reduce ------------------------------------


@drjax.program(partition_size=3)
def broadcast_double_and_sum(x):
    y = drjax.broadcast(x)
    z = drjax.map_fn(lambda a: 2 * a, y)
    return drjax.reduce_sum(z)


print("snippet 2:", broadcast_double_and_sum(jnp.float32(1.0)), "(expect 6)")


# --- Snippets 3-6: parallel MAML + MapReduce AD ----------------------------


def loss(x, y):
    return (x - y) ** 2


def maml_loss(model, lr, task):
    g = jax.grad(loss)(model, task)
    return loss(model - lr * g, task)


@drjax.program(partition_size=3)
def parallel_maml_loss(model, lr, tasks):
    model_b = drjax.broadcast(model)
    lr_b = drjax.broadcast(lr)
    losses = drjax.map_fn(maml_loss, (model_b, lr_b, tasks))
    return drjax.reduce_mean(losses)


args = (jnp.float32(0.0), jnp.float32(0.1), jnp.array([1.0, 2.0, 3.0]))
print("maml loss:", parallel_maml_loss(*args))
print("maml grad:", jax.grad(parallel_maml_loss)(*args),
      "(a DrJAX program too — MapReduce AD)")

# the jaxpr preserves the primitives (paper Snippet 5)
jxp = jax.make_jaxpr(parallel_maml_loss)(*args)
print("\njaxpr:\n", jxp)

# --- §5: interpret out to other platforms ----------------------------------

plan = drjax.build_plan(jxp, 3)
print("\nfederated plan:\n" + plan.to_text())
print("\nbeam pipeline:\n" + plan.to_beam())

outs = drjax.run_plan(plan, *args)
print("\nplan executor result:", outs[0], "== direct:", parallel_maml_loss(*args))

# --- §5 continued: control flow is transparent to the interpreter ----------

# A jitted program yields the SAME plan as the unjitted one: the interpreter
# inlines the pjit sub-jaxpr instead of seeing one opaque eqn.
jit_plan = drjax.build_plan(jax.make_jaxpr(jax.jit(parallel_maml_loss))(*args), 3)
print("\njit(f) plan stage kinds:",
      [s.kind for s in jit_plan.stages],
      "== unjitted:", [s.kind for s in plan.stages])

# A multi-round training loop (lax.scan whose body communicates) becomes one
# LOOP stage holding a sub-plan: per-round communication is explicit.


@drjax.program(partition_size=3)
def two_round_sgd(model, tasks):
    def body(m, _):
        grads = drjax.map_fn(lambda mm, t: 2.0 * (mm - t),
                             (drjax.broadcast(m), tasks))
        g = drjax.reduce_mean(grads)
        return m - 0.1 * g, g

    m, gs = jax.lax.scan(body, model, None, length=2)
    return m, gs


loop_args = (jnp.float32(0.0), jnp.array([1.0, 2.0, 3.0]))
loop_plan = drjax.build_plan(jax.make_jaxpr(two_round_sgd)(*loop_args), 3)
print("\nmulti-round plan (note the LOOP stage):\n" + loop_plan.to_text())
print("\nmulti-round beam pipeline:\n" + loop_plan.to_beam())

loop_outs = drjax.run_plan(loop_plan, *loop_args)
print("\nloop plan executor:", loop_outs[0],
      "== direct:", two_round_sgd(*loop_args)[0])

# --- nested placements: hierarchical MapReduce -----------------------------

# A placement STACK models clients inside pods (paper §6). Values partitioned
# at both levels carry two leading group axes; broadcast/reduce address one
# level with placement=..., and the default spans the whole stack.


@drjax.program(placements={"pods": 2, "clients": 4})
def pod_hierarchical_round(model, tasks):
    model_b = drjax.broadcast(model)                   # server -> (2, 4)
    grads = drjax.map_fn(lambda m, t: 2.0 * (m - t), (model_b, tasks))
    pod_partials = drjax.reduce_mean(grads, placement="clients")  # fast ICI leg
    return drjax.reduce_mean(pod_partials, placement="pods")      # slow DCN leg


tasks = jnp.arange(8, dtype=jnp.float32).reshape(2, 4)
hier_args = (jnp.float32(0.5), tasks)
print("\nhierarchical round:", pod_hierarchical_round(*hier_args))
print("hierarchical grad:", jax.grad(pod_hierarchical_round)(*hier_args),
      "(MapReduce AD is placement-correct)")

# The §5 interpreter stages the two legs as placement-tagged shuffles.
hier_plan = drjax.build_plan(
    jax.make_jaxpr(pod_hierarchical_round)(*hier_args),
    {"pods": 2, "clients": 4},
)
print("\nhierarchical plan (note REDUCE@clients then REDUCE@pods):\n"
      + hier_plan.to_text())
hier_outs = drjax.run_plan(hier_plan, *hier_args)
print("\nhierarchical plan executor:", hier_outs[0],
      "== direct:", pod_hierarchical_round(*hier_args))

# --- compiled plan executor: the whole plan as ONE executable ---------------

# run_plan dispatches each stage eagerly from Python (the reference
# semantics). plan.compile() lowers the ENTIRE plan — loop stages become
# lax.scan/while_loop, adjacent local stages fuse — into one donation-aware
# jitted executable, cached by (plan fingerprint, mesh, arg shapes): calling
# it across rounds triggers exactly one trace, and re-building the same plan
# re-uses the cached executable.

compiled_hier = hier_plan.compile()
print("\ncompiled hierarchical round:", compiled_hier(*hier_args)[0],
      "== run_plan:", hier_outs[0], "(bitwise on CPU)")
compiled_hier(*hier_args)
print("traces after 2 calls:", compiled_hier.trace_count,
      "(one executable, zero retraces across rounds)")

compiled_loop = loop_plan.compile()  # the LOOP-stage trainer from above
print("compiled multi-round trainer:", compiled_loop(*loop_args)[0],
      "== run_plan:", loop_outs[0],
      f"({compiled_loop.num_stage_units} fused stage units,"
      f" scan carry donated in-executable)")

# --- compressed hierarchical reduce: the fused fast path ---------------------

# The per-pod partials are the bytes that cross the slow DCN leg; quantizing
# them to int8 cuts that traffic ~4x. When the compressor is recognized
# (compression.int8_roundtrip carries the drjax_fused_compress tag),
# hierarchical_reduce_mean packs the tree into one (groups..., R, 256) buffer
# per dtype and binds a compress-tagged reduce_mean@clients whose execution
# is a SINGLE pass over the deltas (Pallas reduce+compress kernel on TPU, a
# fused jnp oracle elsewhere). The program still stages as two placement-
# tagged REDUCEs, and grad is identical to the unfused composition — the
# roundtrip is straight-through under MapReduce AD.

from repro.compression import int8_roundtrip


@drjax.program(placements={"pods": 2, "clients": 4})
def compressed_hier_mean(tree):
    return drjax.hierarchical_reduce_mean(tree, compress_fn=int8_roundtrip)


@drjax.program(placements={"pods": 2, "clients": 4})
def reference_hier_mean(tree):
    # use_fused=False forces the generic reduce -> quantize -> dequantize
    # composition (also reachable globally via REPRO_NO_FUSED_REDUCE=1).
    return drjax.hierarchical_reduce_mean(
        tree, compress_fn=int8_roundtrip, use_fused=False
    )


deltas = {"w": jnp.linspace(-1.0, 1.0, 2 * 4 * 6).reshape(2, 4, 6)}
fused_out = compressed_hier_mean(deltas)
ref_out = reference_hier_mean(deltas)
print("\nfused compressed mean:", fused_out["w"],
      "\nreference composition:", ref_out["w"])

g_fused = jax.grad(lambda t: compressed_hier_mean(t)["w"].sum())(deltas)
g_ref = jax.grad(lambda t: reference_hier_mean(t)["w"].sum())(deltas)
print("grad fused == unfused:",
      bool(jnp.all(g_fused["w"] == g_ref["w"])),
      "(straight-through roundtrip)")

fused_plan = drjax.build_plan(
    jax.make_jaxpr(compressed_hier_mean)(deltas), {"pods": 2, "clients": 4}
)
print("\nfused plan (still REDUCE@clients -> REDUCE@pods):\n"
      + fused_plan.to_text())

# --- static analysis: verify the plan WITHOUT running it --------------------

# plan.analyze() runs every static pass: placement safety (the full-depth
# generalization of check_locality — comm-free local stages even inside
# cond branches and while predicates, broadcast/reduce pairing), donation/
# aliasing (use-after-donate, why a donation would be dropped), retrace
# hazards (a scalar folded into the captured consts defeats the executable
# cache), and a per-stage communication-cost model read off the IR.

report = hier_plan.analyze(donate_argnums=(0,))
report.raise_if_errors()  # the oracle-suite gate: no errors, statically
print("\nstatic analysis of the hierarchical round:", report)

# The comm-cost pass splits the wire bytes by fabric: the clients-level
# shuffle rides fast intra-pod ICI, only the pods-level leg crosses the
# slow DCN — and a compress-tagged reduce is costed in its actual packed
# int8+per-256-block-scales wire format, not naive f32/4.
cost = fused_plan.comm_cost()
print("fused plan comm cost: dcn_bytes=%.0f ici_bytes=%.0f" % (
    cost.dcn_bytes, cost.ici_bytes))
for c in cost.per_stage:
    print(f"  {c.stage}: {c.op}@{c.placement} over {c.link}, "
          f"{c.wire_format}, {c.wire_bytes:.0f} B")

# --- N-level stacks: three replica levels ------------------------------------

# Placement stacks are no longer capped at two levels. A 3-level
# (superpods, pods, clients) stack factorizes onto a
# ("superpod", "pod", "data") mesh — `mesh_for_placements` accepts any
# ordered stack and `placement_axes_for` names each level's mesh axis.
# Reductions chain innermost-out, one fabric leg per level.


@drjax.program(placements={"superpods": 2, "pods": 2, "clients": 2})
def three_level_round(model, tasks):
    grads = drjax.map_fn(lambda m, t: 2.0 * (m - t),
                         (drjax.broadcast(model), tasks))
    p1 = drjax.reduce_mean(grads, placement="clients")    # intra-pod ICI
    p2 = drjax.reduce_mean(p1, placement="pods")          # intra-superpod
    return drjax.reduce_mean(p2, placement="superpods")   # cross-superpod DCN


tasks3 = jnp.arange(8, dtype=jnp.float32).reshape(2, 2, 2)
print("\n3-level round:", three_level_round(jnp.float32(0.5), tasks3))

# --- pipeline-stage placements: a 1F1B microbatch round ----------------------

# A placement can carry kind="stages" instead of the default "replicas":
# groups are pipeline stages, not data replicas. Broadcast/reduce are
# rejected at a stage level; per-stage compute is `stage_map` (one fn per
# stage) and stage-to-stage movement is `stage_transfer` (a shift along the
# stage axis — its transpose is the backward pipeline, free from AD).
# `make_pipelined_round` packages the fill/drain (1F1B) schedule: S stages
# and M microbatches run in M + S - 1 ticks under one lax.scan.

from repro.algorithms import (
    PipelineConfig, make_pipelined_round, pipeline_bubble_fraction,
)

S, M, D = 2, 4, 8
stage_fns = tuple((lambda s: (lambda x: x * (s + 1.0)))(s) for s in range(S))
round_fn = make_pipelined_round(
    stage_fns, PipelineConfig(num_stages=S, num_microbatches=M))

mbs = jnp.arange(M * D, dtype=jnp.float32).reshape(M, D)
act0 = jnp.zeros((S, D), jnp.float32)
outs, _ = round_fn(mbs, act0)
print("\npipelined round outs[0]:", outs[0],
      "(== stage chain applied to microbatch 0)")
print("bubble fraction (S-1)/(M+S-1):", pipeline_bubble_fraction(S, M))

# The interpreter stages the schedule as one LOOP whose body carries a
# TRANSFER eqn; plan.compile() lowers it to a single donation-aware
# executable, still bitwise-equal to the eager run_plan oracle.
pipe_plan = drjax.build_plan(
    jax.make_jaxpr(round_fn)(mbs, act0),
    round_fn.drjax_context,
    partitioned_invars=(0, 1),  # M may equal S; skip the shape heuristic
)
print("\npipelined plan (note the [stages] level and TRANSFER):\n"
      + pipe_plan.to_text())
compiled_pipe = pipe_plan.compile()
print("compiled pipeline:", compiled_pipe(mbs, act0)[0][0],
      "== run_plan:", drjax.run_plan(pipe_plan, mbs, act0)[0][0])
