"""Parallel MAML on a real (small) transformer via MapReduce AD.

Meta-learns an initialization across synthetic "task domains" (group-skewed
token distributions): each task adapts with one inner SGD step on its support
batch; the outer loss is the post-adaptation query loss, averaged with
``drjax.reduce_mean``. ``jax.grad`` of the whole thing is again a DrJAX
program (paper Snippet 7).

Run:  PYTHONPATH=src python examples/parallel_maml.py
"""

import functools

import jax
import jax.numpy as jnp

from repro import core as drjax
from repro.algorithms.maml import make_parallel_maml
from repro.data.grouped import GroupedCorpus, CohortSampler
from repro.models import registry

N_TASKS = 4
INNER_LR = 0.05
OUTER_LR = 0.2
STEPS = 30


def main():
    cfg = registry.get_config("lm_350m").reduced()
    loss_fn = functools.partial(registry.loss_fn, cfg)
    params = registry.init_params(jax.random.PRNGKey(0), cfg)

    corpus = GroupedCorpus(vocab_size=cfg.vocab_size, num_groups=N_TASKS * 4)
    sampler = CohortSampler(corpus, cohort_size=N_TASKS)

    maml_loss, train_step = make_parallel_maml(
        loss_fn, partition_size=N_TASKS, inner_lr=INNER_LR, inner_steps=1
    )
    step = jax.jit(functools.partial(train_step, outer_lr=OUTER_LR))

    def tasks_for(round_idx):
        d = sampler.round_batch(round_idx, 2, 2, 32)  # 2 local batches/task
        return {
            "support": {"tokens": d["tokens"][:, 0], "labels": d["labels"][:, 0]},
            "query": {"tokens": d["tokens"][:, 1], "labels": d["labels"][:, 1]},
        }

    t0 = tasks_for(0)
    print(f"initial meta-loss: {maml_loss(params, t0):.4f}")
    for r in range(STEPS):
        params, loss = step(params, tasks_for(r))
        if r % 5 == 0:
            print(f"round {r:3d}  meta-loss {float(loss):.4f}")
    print(f"final meta-loss:   {maml_loss(params, t0):.4f}")

    # show the MapReduce structure of the *gradient* program
    gx = jax.make_jaxpr(jax.grad(maml_loss))(params, t0)
    counts = drjax.count_primitives(gx)
    print("gradient-program primitives:", counts)


if __name__ == "__main__":
    main()
