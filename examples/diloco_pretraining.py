"""End-to-end driver: DiLoCo pretraining of the paper's 350M-class LM.

Trains a reduced-width model for a few hundred rounds on the group-
partitioned synthetic corpus, with fault injection + checkpoint recovery —
the full production loop at CPU scale. (~2-3 min on CPU.)

Run:  PYTHONPATH=src python examples/diloco_pretraining.py [--rounds 200]
"""

import argparse
import subprocess
import sys
import os

HERE = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    args = ap.parse_args()

    env = dict(os.environ, PYTHONPATH=os.path.join(HERE, "src"))
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "lm_350m", "--reduced",
        "--algorithm", "diloco",
        "--rounds", str(args.rounds),
        "--cohort", "8", "--local-steps", "4",
        "--batch", "4", "--seq", "64",
        "--ckpt-dir", "/tmp/repro_diloco_ckpt",
        "--fail-at", "25", "120",          # injected node failures
        "--stragglers",                     # deadline-masked reductions
    ]
    print("+", " ".join(cmd))
    raise SystemExit(subprocess.call(cmd, env=env, cwd=HERE))


if __name__ == "__main__":
    main()
