"""Chaos soak quickstart: composed fault injection with hard invariants.

Trains a hierarchical round while a deterministic, seeded schedule injects
overlapping adversity — device failures, pod dropout/regrowth, log-normal
stragglers with deadline masking, torn/corrupt checkpoints, and serve
traffic with a scheduler fault — then asserts the production invariants:
bitwise-identical final state vs an uninterrupted oracle, zero per-client
retraces, masked tail latency strictly below the synchronous baseline, and
an unbiased masked mean. (~15 s on CPU.)

Run:  PYTHONPATH=src python examples/chaos_soak.py [--rounds 48]
      PYTHONPATH=src python examples/chaos_soak.py --minutes 5

``--minutes`` replaces the fixed round count with a wall-clock budget: the
soak times one calibration round, scales rounds (and fault counts,
proportionally) to fill the budget, and then runs the scaled schedule.
"""

import argparse
import json

from repro.runtime.chaos import ChaosConfig, ChaosSchedule, run_chaos_soak


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=48)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--minutes", type=float, default=None,
                    help="wall-clock budget: calibrate one round, then "
                         "scale rounds and fault counts to fill this many "
                         "minutes (overrides --rounds)")
    args = ap.parse_args()

    cfg = ChaosConfig(rounds=args.rounds, seed=args.seed,
                      minutes=args.minutes)
    if args.minutes is None:
        schedule = ChaosSchedule.from_config(cfg)
        print(f"schedule: failures at {schedule.failure_rounds}, "
              f"elastic events {schedule.elastic_events}, "
              f"checkpoint faults {schedule.ckpt_faults}, "
              f"serve bursts at {schedule.serve_rounds}")
    else:
        # The schedule depends on the round count, which is unknown until
        # the calibration round inside run_chaos_soak has been timed.
        print(f"time-budgeted soak: calibrating to fill "
              f"{args.minutes:g} min")

    # run_chaos_soak raises AssertionError if any invariant is violated
    report = run_chaos_soak(cfg)

    print(json.dumps(report.to_json(), indent=2))
    print(f"\nsurvived {report.device_failures} device failures, "
          f"{len(report.elastic_events)} elastic events, "
          f"{len(report.ckpt_faults_injected)} checkpoint faults "
          f"({report.fallback_restores} fallback restores); "
          f"bitwise-identical to oracle: {report.oracle_bitwise_equal}; "
          f"client-leg retraces: {report.client_retraces}; "
          f"straggler speedup: {report.straggler['speedup']}x")


if __name__ == "__main__":
    main()
