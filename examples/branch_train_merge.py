"""Branch-Train-Merge over domain-partitioned data (Li et al. 2022).

Each domain branch trains an expert from a shared seed; experts merge by
(weighted) parameter averaging — one DrJAX broadcast → map → reduce. Also
demos serving the merged model with the batched scheduler.

Run:  PYTHONPATH=src python examples/branch_train_merge.py
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.algorithms.btm import branch_train_merge
from repro.data.grouped import CohortSampler, GroupedCorpus
from repro.models import registry

N_DOMAINS = 4
TRAIN_STEPS = 20


def main():
    cfg = registry.get_config("lm_350m").reduced()
    loss_fn = functools.partial(registry.loss_fn, cfg)
    seed_params = registry.init_params(jax.random.PRNGKey(0), cfg)

    corpus = GroupedCorpus(vocab_size=cfg.vocab_size, num_groups=N_DOMAINS)
    sampler = CohortSampler(corpus, cohort_size=N_DOMAINS)
    d = sampler.round_batch(0, TRAIN_STEPS, 2, 32)
    domain_data = {"tokens": d["tokens"], "labels": d["labels"]}

    for merge in ("mean", "weighted"):
        btm = jax.jit(branch_train_merge(
            loss_fn, optim.sgd(0.05), partition_size=N_DOMAINS,
            train_steps=TRAIN_STEPS, merge=merge,
        ))
        merged, metrics = btm(seed_params, domain_data)
        batch = {"tokens": d["tokens"][0, 0], "labels": d["labels"][0, 0]}
        print(f"merge={merge:9s} mean-final-expert-loss="
              f"{float(metrics['mean_final_loss']):.4f} "
              f"merged-model-loss={float(loss_fn(merged, batch)):.4f}")

    # quick greedy generation from the merged model
    from repro.models import transformer
    prompt = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (1, 8)),
        jnp.int32,
    )
    last, caches = transformer.prefill(cfg, merged, prompt, max_len=16)
    toks = []
    tok = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    for _ in range(8):
        toks.append(int(tok[0, 0]))
        logits, caches = transformer.decode_step(cfg, merged, tok, caches)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    print("greedy continuation token ids:", toks)


if __name__ == "__main__":
    main()
